"""Mixture-of-Experts: token-choice top-k routing with capacity dispatch.

Design notes (these matter for the sharding story — see DESIGN.md §5):

* Routing is computed *per batch row* and dispatch/combine are gathers and
  scatter-adds along the sequence axis — every op is batch-parallel, so the
  data-axis sharding is untouched and no one-hot (T, E, C) dispatch tensor
  is ever built.
* Expert FFNs run as expert-batched einsums ``(B, E, C, d) x (E, d, f)``:
  with experts divisible by the model axis the E dimension shards (expert
  parallelism, zero weight movement); otherwise the planner shards `f`
  (tensor parallelism within each expert — mixtral's 8 experts on a 16-way
  axis).
* Capacity C = ceil(S * k / E * capacity_factor); overflow tokens are
  dropped (GShard semantics) — the combine scatter simply adds nothing for
  them, and the router's auxiliary load-balancing loss pushes the overflow
  rate down.
* Decode (S == 1 per step): dispatch degenerates, so we run the dense-
  all-experts path masked by the gates. Decode is HBM-bandwidth-bound on
  expert weights, which are read in full either way — the extra FLOPs are
  free in roofline terms (documented in EXPERIMENTS.md).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init
from repro.sharding.act import constrain_batch, constrain_expert_batch


def moe_init(
    key, d_model: int, d_ff: int, n_experts: int, dtype
) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    return {
        "router": dense_init(kr, d_model, n_experts, jnp.float32),
        "w_gate": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(kg, n_experts)
        ),
        "w_up": jax.vmap(lambda k: dense_init(k, d_model, d_ff, dtype))(
            jax.random.split(ku, n_experts)
        ),
        "w_down": jax.vmap(lambda k: dense_init(k, d_ff, d_model, dtype))(
            jax.random.split(kd, n_experts)
        ),
    }


def router_probs(params: Params, x: jax.Array) -> jax.Array:
    logits = jnp.einsum(
        "bsd,de->bse", x.astype(jnp.float32), params["router"]
    )
    return jax.nn.softmax(logits, axis=-1)


def moe_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    return_aux: bool = False,
) -> jax.Array | tuple[jax.Array, dict[str, jax.Array]]:
    B, S, d = x.shape
    E = params["router"].shape[1]
    if S == 1:
        out = _moe_dense_decode(params, x, top_k=top_k)
        return (out, {}) if return_aux else out

    probs = router_probs(params, x)  # (B, S, E) f32
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (B, S, k)
    # renormalize the selected gates (mixtral convention)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    C = int(max(1, -(-S * top_k // E) * capacity_factor))  # ceil * factor
    C = min(C, S)

    # position of each (token, k) entry within its expert's queue:
    # flatten (S, k) in token-major order, cumulative count per expert.
    flat_expert = expert_idx.reshape(B, S * top_k)  # (B, S*k)
    onehot = jax.nn.one_hot(flat_expert, E, dtype=jnp.int32)  # (B, S*k, E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot  # (B, S*k, E)
    pos = jnp.take_along_axis(
        pos_in_expert, flat_expert[..., None], axis=-1
    )[..., 0]  # (B, S*k)
    keep = pos < C  # overflow dropped

    # dispatch table: for every expert slot (e, c) the source token index
    # (or S => padding row).
    slot = flat_expert * C + pos  # (B, S*k) in [0, E*C)
    token_of_entry = jnp.repeat(jnp.arange(S)[:, None], top_k, axis=1).reshape(-1)
    dispatch = jnp.full((B, E * C), S, jnp.int32)
    dispatch = jax.vmap(
        lambda dsp, slt, kp: dsp.at[jnp.where(kp, slt, E * C)].set(
            token_of_entry, mode="drop"
        )
    )(dispatch, slot, keep)

    # gather tokens into expert-major layout (padding row of zeros at S)
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad, dispatch[..., None], axis=1
    )  # (B, E*C, d)
    xe = constrain_expert_batch(xe.reshape(B, E, C, d))

    # expert FFN (SwiGLU), expert-batched
    g = jnp.einsum(
        "becd,edf->becf", xe, params["w_gate"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    u = jnp.einsum(
        "becd,edf->becf", xe, params["w_up"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    h = jax.nn.silu(g) * u
    ye = constrain_expert_batch(
        jnp.einsum(
            "becf,efd->becd",
            h,
            params["w_down"],
            preferred_element_type=jnp.float32,
        ).astype(x.dtype)
    )  # (B, E, C, d)

    # combine: scatter-add each expert slot's output back to its token,
    # weighted by its gate value.
    gates_flat = (gate_vals.reshape(B, S * top_k) * keep).astype(x.dtype)
    gate_of_slot = jnp.zeros((B, E * C), x.dtype)
    gate_of_slot = jax.vmap(
        lambda gs, slt, gv, kp: gs.at[jnp.where(kp, slt, E * C)].set(
            gv, mode="drop"
        )
    )(gate_of_slot, slot, gates_flat, keep)
    ye = ye.reshape(B, E * C, d) * gate_of_slot[..., None]
    y = jnp.zeros((B, S + 1, d), x.dtype)
    y = jax.vmap(lambda ya, dsp, yv: ya.at[dsp].add(yv, mode="drop"))(
        y, dispatch, ye
    )
    y = constrain_batch(y[:, :S])

    if not return_aux:
        return y
    # load-balancing auxiliary loss (Switch/GShard): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))  # mean router prob per expert
    fe = jnp.mean(
        (jax.nn.one_hot(expert_idx, E).sum(axis=2) > 0).astype(jnp.float32),
        axis=(0, 1),
    )
    aux = {
        "load_balance_loss": E * jnp.sum(me * fe),
        "overflow_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return y, aux


def _moe_dense_decode(params: Params, x: jax.Array, *, top_k: int) -> jax.Array:
    """Decode path: all experts computed, combined with top-k gates.
    HBM bytes (the decode bottleneck) are identical to an ideal dispatch —
    every expert's weights stream through once per step regardless."""
    B, S, d = x.shape
    E = params["router"].shape[1]
    probs = router_probs(params, x)  # (B, 1, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    mask = jnp.zeros((B, S, E), jnp.float32)
    mask = jax.vmap(
        jax.vmap(lambda m, idx, gv: m.at[idx].add(gv))
    )(mask, expert_idx, gate_vals)  # (B, S, E) gate weight per expert

    g = jnp.einsum(
        "bsd,edf->bsef", x, params["w_gate"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    u = jnp.einsum(
        "bsd,edf->bsef", x, params["w_up"], preferred_element_type=jnp.float32
    ).astype(x.dtype)
    h = jax.nn.silu(g) * u
    y = jnp.einsum(
        "bsef,efd->bsed", h, params["w_down"], preferred_element_type=jnp.float32
    )
    return jnp.sum(y * mask[..., None].astype(y.dtype), axis=2).astype(x.dtype)


def moe_reference(params: Params, x: jax.Array, *, top_k: int) -> jax.Array:
    """Oracle: loop over tokens/experts densely (no capacity drops).
    Matches moe_apply exactly when nothing overflows."""
    B, S, d = x.shape
    E = params["router"].shape[1]
    probs = router_probs(params, x)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    out = jnp.zeros_like(x)
    for e in range(E):
        g = jax.nn.silu(x @ params["w_gate"][e]) * (x @ params["w_up"][e])
        ye = (g @ params["w_down"][e]).astype(x.dtype)
        w = jnp.sum(
            jnp.where(expert_idx == e, gate_vals, 0.0), axis=-1
        )  # (B, S)
        out = out + ye * w[..., None].astype(x.dtype)
    return out
