"""Mamba (selective SSM) block — chunked parallel scan + recurrent decode.

Structure follows Mamba-1 as used by Jamba: in_proj -> (x, z); causal
depthwise conv on x; silu; input-dependent (dt, B, C); selective state
update h_t = exp(dt*A) h_{t-1} + dt*B x_t; y = C·h + D*x; gated by silu(z);
out_proj.

Train/prefill runs a *chunked* scan: within a chunk of `chunk` timesteps an
associative scan runs in parallel; a lax.scan carries the (inner, d_state)
state across chunks. This bounds the materialized (B, chunk, inner, state)
discretized tensors — the same blocking the Pallas kernel
(repro.kernels.ssm_scan) uses on TPU VMEM.

Decode keeps state = {ssm: (B, inner, d_state), conv: (B, K-1, inner)} —
O(1) per token, which is what makes the hybrid archs long_500k-capable.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init


def mamba_init(
    key,
    d_model: int,
    *,
    expand: int = 2,
    d_state: int = 16,
    d_conv: int = 4,
    dt_rank: int | None = None,
    dtype=jnp.bfloat16,
) -> Params:
    inner = expand * d_model
    dt_rank = dt_rank or max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 6)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, d_state + 1, dtype=jnp.float32), (inner, 1))
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * inner, dtype),
        "conv_w": (
            jax.random.normal(ks[1], (d_conv, inner), jnp.float32) / math.sqrt(d_conv)
        ).astype(dtype),
        "conv_b": jnp.zeros((inner,), jnp.float32),
        "x_proj": dense_init(ks[2], inner, dt_rank + 2 * d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, inner, dtype),
        "dt_bias": jnp.log(
            jnp.exp(
                jnp.exp(
                    jax.random.uniform(ks[4], (inner,), jnp.float32)
                    * (math.log(0.1) - math.log(0.001))
                    + math.log(0.001)
                )
            )
            - 1.0
        ),  # softplus^-1 of dt in [1e-3, 1e-1]
        "A_log": jnp.log(a),
        "D": jnp.ones((inner,), jnp.float32),
        "out_proj": dense_init(ks[5], inner, d_model, dtype),
    }


def _split_xz(params: Params, u: jax.Array) -> tuple[jax.Array, jax.Array]:
    xz = jnp.einsum(
        "bsd,df->bsf", u, params["in_proj"], preferred_element_type=jnp.float32
    ).astype(u.dtype)
    inner = xz.shape[-1] // 2
    return xz[..., :inner], xz[..., inner:]


def _conv_causal(params: Params, x: jax.Array, init: jax.Array | None = None):
    """Depthwise causal conv along S. x: (B, S, inner).
    Returns (y, tail) where tail = last K-1 inputs (decode conv state)."""
    K = params["conv_w"].shape[0]
    if init is None:
        init = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([init.astype(x.dtype), x], axis=1)  # (B, S+K-1, inner)
    y = jnp.zeros(x.shape, jnp.float32)
    for i in range(K):
        y = y + xp[:, i : i + x.shape[1]].astype(jnp.float32) * params["conv_w"][
            i
        ].astype(jnp.float32)
    y = y + params["conv_b"]
    tail = xp[:, xp.shape[1] - (K - 1) :]
    return y.astype(x.dtype), tail


def _dt_b_c(params: Params, x: jax.Array, d_state: int):
    """x: (B, S, inner) -> dt (B,S,inner) f32, Bmat/Cmat (B,S,state) f32."""
    proj = jnp.einsum(
        "bsi,ir->bsr", x, params["x_proj"], preferred_element_type=jnp.float32
    )
    dt_rank = proj.shape[-1] - 2 * d_state
    dt_low, Bm, Cm = (
        proj[..., :dt_rank],
        proj[..., dt_rank : dt_rank + d_state],
        proj[..., dt_rank + 2 * d_state - d_state :],
    )
    dt = jnp.einsum(
        "bsr,ri->bsi",
        dt_low.astype(x.dtype),
        params["dt_proj"],
        preferred_element_type=jnp.float32,
    )
    dt = jax.nn.softplus(dt + params["dt_bias"])
    return dt, Bm, Cm


def _ssm_binop(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def mamba_scan_chunked(
    dt: jax.Array,  # (B, S, inner) f32
    Bm: jax.Array,  # (B, S, state) f32
    Cm: jax.Array,  # (B, S, state) f32
    x: jax.Array,  # (B, S, inner)
    A: jax.Array,  # (inner, state) f32 (negative)
    *,
    chunk: int = 256,
    h0: jax.Array | None = None,  # (B, inner, state) f32
    scan_dtype=jnp.float32,
) -> tuple[jax.Array, jax.Array]:
    """Selective scan. Returns (y (B,S,inner) f32, h_final)."""
    B, S, inner = dt.shape
    state = Bm.shape[-1]
    chunk = min(chunk, S)
    S_orig = S
    if S % chunk:  # ragged tail: pad with dt=0 (identity transition)
        pad = chunk - S % chunk
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    n = S // chunk
    if h0 is None:
        h0 = jnp.zeros((B, inner, state), jnp.float32)

    dt_c = dt.reshape(B, n, chunk, inner).swapaxes(0, 1)
    B_c = Bm.reshape(B, n, chunk, state).swapaxes(0, 1)
    C_c = Cm.reshape(B, n, chunk, state).swapaxes(0, 1)
    x_c = x.reshape(B, n, chunk, inner).swapaxes(0, 1)

    scan_dtype = jnp.dtype(scan_dtype)

    def chunk_step(h, inputs):
        dt_i, B_i, C_i, x_i = inputs  # (B, c, ...)
        # discretize: Abar (B,c,inner,state), Bx (B,c,inner,state)
        Abar = jnp.exp(dt_i[..., None] * A[None, None])  # broadcast
        Bx = (dt_i * x_i.astype(jnp.float32))[..., None] * B_i[..., None, :]
        # seed the recurrence with the carry: fold h into the first element
        Bx = Bx.at[:, 0].add(Abar[:, 0] * h)
        Aacc, Hall = jax.lax.associative_scan(
            _ssm_binop,
            (Abar.astype(scan_dtype), Bx.astype(scan_dtype)),
            axis=1,
        )
        y = jnp.einsum(
            "bcis,bcs->bci", Hall, C_i.astype(scan_dtype),
            preferred_element_type=jnp.float32,
        )
        return Hall[:, -1].astype(jnp.float32), y.astype(jnp.float32)

    h_final, ys = jax.lax.scan(chunk_step, h0, (dt_c, B_c, C_c, x_c))
    y = ys.swapaxes(0, 1).reshape(B, S, inner)[:, :S_orig]
    return y, h_final


def mamba_apply(
    params: Params,
    u: jax.Array,  # (B, S, d)
    *,
    d_state: int,
    chunk: int = 256,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
    scan_dtype=jnp.float32,
) -> Any:
    """Full mamba mixer. If `state` given, continues from it (prefill
    chaining); if `return_state`, also returns {ssm, conv} for decode."""
    x, z = _split_xz(params, u)
    conv_init = state["conv"] if state is not None else None
    x_conv, conv_tail = _conv_causal(params, x, conv_init)
    x_act = jax.nn.silu(x_conv.astype(jnp.float32)).astype(u.dtype)
    dt, Bm, Cm = _dt_b_c(params, x_act, d_state)
    A = -jnp.exp(params["A_log"])
    h0 = state["ssm"] if state is not None else None
    y, h = mamba_scan_chunked(
        dt, Bm, Cm, x_act, A, chunk=chunk, h0=h0, scan_dtype=scan_dtype
    )
    y = y + x_act.astype(jnp.float32) * params["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = jnp.einsum(
        "bsi,id->bsd",
        y.astype(u.dtype),
        params["out_proj"],
        preferred_element_type=jnp.float32,
    ).astype(u.dtype)
    if not return_state:
        return out
    return out, {"ssm": h, "conv": conv_tail}


def mamba_decode(
    params: Params,
    u: jax.Array,  # (B, 1, d)
    state: dict[str, jax.Array],
    *,
    d_state: int,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """O(1) single-token step."""
    out, new_state = mamba_apply(
        params, u, d_state=d_state, chunk=1, state=state, return_state=True
    )
    return out, new_state
