"""Shared neural-net building blocks (pure functions over param pytrees).

Conventions:
* params are nested dicts of jnp arrays; weights stored in `param_dtype`
  (bf16 for the large configs), matmuls accumulate in f32 via
  ``preferred_element_type``;
* no biases on projection layers (llama convention) unless stated;
* every function is shape-polymorphic over batch/sequence so the same code
  serves train (B,S), prefill (B,S) and decode (B,1).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


# --------------------------------------------------------------------- #
# init helpers                                                          #
# --------------------------------------------------------------------- #
def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------- #
# norms                                                                 #
# --------------------------------------------------------------------- #
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rms_norm_init(d: int) -> jax.Array:
    # zero-centered scale (gemma convention: weight = 1 + scale)
    return jnp.zeros((d,), jnp.float32)


# --------------------------------------------------------------------- #
# rotary position embeddings                                            #
# --------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    dt = x.dtype
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = jnp.split(x32, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dt)


# --------------------------------------------------------------------- #
# projections                                                           #
# --------------------------------------------------------------------- #
def linear(x: jax.Array, w: jax.Array) -> jax.Array:
    # f32 accumulation, cast at the boundary. (Hillclimb H1.2 tried bf16
    # register types to shrink TP all-reduces; XLA's excess-precision pass
    # re-promoted the reduces to f32 and the extra converts only grew the
    # byte count — refuted, reverted. See EXPERIMENTS.md §Perf.)
    return jnp.einsum(
        "...d,df->...f", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


# --------------------------------------------------------------------- #
# SwiGLU MLP                                                            #
# --------------------------------------------------------------------- #
def swiglu_init(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d, d_ff, dtype),
        "w_up": dense_init(k2, d, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d, dtype),
    }


def swiglu(params: Params, x: jax.Array) -> jax.Array:
    g = linear(x, params["w_gate"])
    u = linear(x, params["w_up"])
    return linear(jax.nn.silu(g) * u, params["w_down"])


# --------------------------------------------------------------------- #
# embedding / chunked cross-entropy                                     #
# --------------------------------------------------------------------- #
def embed(tok_table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(tok_table, tokens, axis=0)


def chunked_softmax_xent(
    hidden: jax.Array,  # (B, S, d)
    w_unembed: jax.Array,  # (d, V) — V possibly padded for sharding
    labels: jax.Array,  # (B, S) int32; -1 => masked out
    chunk: int = 1024,
    logit_softcap: float | None = None,
    valid_vocab: int | None = None,  # mask padded vocab columns
) -> jax.Array:
    """Mean cross-entropy without materializing (B, S, V) logits.

    Scans over sequence chunks; each chunk's logits live only inside one
    scan step (V can be 262k — the full logits would be tens of GB).
    Returns the mean NLL over unmasked positions (f32 scalar).
    """
    B, S, d = hidden.shape
    n_chunks = max(1, S // chunk)
    assert S % n_chunks == 0, (S, chunk)
    c = S // n_chunks
    h = hidden.reshape(B, n_chunks, c, d).swapaxes(0, 1)  # (n, B, c, d)
    y = labels.reshape(B, n_chunks, c).swapaxes(0, 1)  # (n, B, c)
    V = w_unembed.shape[1]

    def step(carry, xs):
        loss_sum, count = carry
        h_c, y_c = xs
        logits = jnp.einsum(
            "bcd,dv->bcv", h_c, w_unembed, preferred_element_type=jnp.float32
        )
        if logit_softcap is not None:
            logits = logit_softcap * jnp.tanh(logits / logit_softcap)
        if valid_vocab is not None and valid_vocab < V:
            logits = jnp.where(
                (jnp.arange(V) < valid_vocab)[None, None, :], logits, -1e30
            )
        lse = jax.nn.logsumexp(logits, axis=-1)  # (B, c)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(y_c, 0)[..., None], axis=-1
        )[..., 0]
        mask = (y_c >= 0).astype(jnp.float32)
        loss_sum = loss_sum + jnp.sum((lse - gold) * mask)
        count = count + jnp.sum(mask)
        return (loss_sum, count), None

    (loss_sum, count), _ = jax.lax.scan(
        step, (jnp.float32(0.0), jnp.float32(0.0)), (h, y)
    )
    return loss_sum / jnp.maximum(count, 1.0)


def logits_for_last(
    hidden_last: jax.Array,  # (B, 1, d)
    w_unembed: jax.Array,
    logit_softcap: float | None = None,
    valid_vocab: int | None = None,
) -> jax.Array:
    logits = jnp.einsum(
        "bsd,dv->bsv", hidden_last, w_unembed, preferred_element_type=jnp.float32
    )
    if logit_softcap is not None:
        logits = logit_softcap * jnp.tanh(logits / logit_softcap)
    V = w_unembed.shape[1]
    if valid_vocab is not None and valid_vocab < V:
        logits = jnp.where(
            (jnp.arange(V) < valid_vocab)[None, None, :], logits, -1e30
        )
    return logits


def pad_to_multiple(n: int, multiple: int) -> int:
    return ((n + multiple - 1) // multiple) * multiple
