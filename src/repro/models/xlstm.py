"""xLSTM blocks: mLSTM (matrix memory, parallelizable) and sLSTM (scalar
memory, recurrent), after Beck et al. 2024 (arXiv:2405.04517).

Width note (documented deviation, see DESIGN.md): the cells operate at
d_model width with H heads (q/k/v/z/out projections d->d, gates d->H),
which lands the assigned 48L/2048d/4H config at ~1.2B params — the
assignment's d_ff=0 rules out the paper's separate FFN sublayer, and this
width choice matches the 1.3B budget closest.

mLSTM train/prefill uses the chunked parallel ("quasi-attention") form with
the paper's max-stabilizer; decode keeps per-head matrix memory
C (B,H,D,D), normalizer n (B,H,D) and stabilizer m (B,H).

sLSTM is inherently sequential (recurrent gate inputs): lax.scan over time
with block-diagonal (per-head) recurrent weights.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import Params, dense_init, linear

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# mLSTM                                                                 #
# --------------------------------------------------------------------- #
def mlstm_init(key, d_model: int, n_heads: int, dtype) -> Params:
    ks = jax.random.split(key, 7)
    return {
        "wq": dense_init(ks[0], d_model, d_model, dtype),
        "wk": dense_init(ks[1], d_model, d_model, dtype),
        "wv": dense_init(ks[2], d_model, d_model, dtype),
        "wz": dense_init(ks[3], d_model, d_model, dtype),  # output gate
        "wo": dense_init(ks[4], d_model, d_model, dtype),
        "w_igate": dense_init(ks[5], d_model, n_heads, jnp.float32),
        "w_fgate": dense_init(ks[6], d_model, n_heads, jnp.float32),
        "b_igate": jnp.zeros((n_heads,), jnp.float32),
        # forget bias init positive => long memory at init
        "b_fgate": jnp.full((n_heads,), 3.0, jnp.float32),
    }


def _mlstm_gates(params: Params, x: jax.Array):
    x32 = x.astype(jnp.float32)
    i_raw = x32 @ params["w_igate"] + params["b_igate"]  # (B,S,H)
    f_raw = x32 @ params["w_fgate"] + params["b_fgate"]
    return i_raw, f_raw


def mlstm_parallel(
    q: jax.Array,  # (B,S,H,D)
    k: jax.Array,
    v: jax.Array,
    i_raw: jax.Array,  # (B,S,H) pre-activation input gate
    f_raw: jax.Array,  # (B,S,H) pre-activation forget gate
    *,
    q_block: int = 256,
    kv_block: int = 256,
    f_carry: jax.Array | None = None,  # (B,H) cumulative logf before t=0
) -> jax.Array:
    """Chunked parallel mLSTM with running-max stabilization."""
    B, S, H, D = q.shape
    scale = 1.0 / math.sqrt(D)
    bq, bk = min(q_block, S), min(kv_block, S)
    assert S % bq == 0 and S % bk == 0
    nq, nk = S // bq, S // bk

    logf = jax.nn.log_sigmoid(f_raw)  # (B,S,H)
    F = jnp.cumsum(logf, axis=1)  # inclusive cumsum: F_t = sum_{u<=t} logf_u
    if f_carry is not None:
        F = F + f_carry[:, None, :]
    # decay exponent for s <= t: (F_t - F_s) + i_s   (i at s includes its own
    # input gate; forget gates strictly after s up to t: F_t - F_s)
    G = F.transpose(0, 2, 1)  # (B,H,S)
    I = i_raw.transpose(0, 2, 1)  # (B,H,S)

    qb = (q * scale).reshape(B, nq, bq, H, D)
    kb = k.reshape(B, nk, bk, H, D)
    vb = v.reshape(B, nk, bk, H, D)
    Gq = G.reshape(B, H, nq, bq)
    Gk = G.reshape(B, H, nk, bk)
    Ik = I.reshape(B, H, nk, bk)
    q_pos = jnp.arange(S).reshape(nq, bq)
    k_pos = jnp.arange(S).reshape(nk, bk)
    logf_k = logf.transpose(0, 2, 1).reshape(B, H, nk, bk)

    def q_step(_, qi):
        q_i = qb[:, qi]  # (B,bq,H,D)
        g_q = Gq[:, :, qi]  # (B,H,bq)

        def kv_step(carry, ki):
            m, num, den = carry
            k_i, v_i = kb[:, ki], vb[:, ki]
            # decay D̃_ts = F_t - (F_s - logf_s) ... note: standard mLSTM
            # uses D̃ = F_t - F_s + i_s with F inclusive and the convention
            # that position s contributes k_s scaled by i_s and forget
            # gates f_{s+1..t}: F_t - F_s = sum_{u=s+1..t} logf_u. ✓
            dtil = (
                g_q[..., None]
                - Gk[:, :, ki][..., None, :]
                + Ik[:, :, ki][..., None, :]
            )  # (B,H,bq,bk)
            mask = q_pos[qi][:, None] >= k_pos[ki][None, :]
            dtil = jnp.where(mask[None, None], dtil, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(dtil, axis=-1))  # (B,H,bq)
            w = jnp.exp(dtil - m_new[..., None])
            qk = jnp.einsum(
                "bthd,bshd->bhts", q_i, k_i, preferred_element_type=jnp.float32
            )
            sc = qk * w
            alpha = jnp.exp(m - m_new)
            num_new = num * alpha[..., None] + jnp.einsum(
                "bhts,bshd->bhtd",
                sc.astype(v_i.dtype),
                v_i,
                preferred_element_type=jnp.float32,
            )
            den_new = den * alpha + jnp.sum(sc, axis=-1)
            return (m_new, num_new, den_new), None

        m0 = jnp.full((B, H, bq), NEG_INF, jnp.float32)
        n0 = jnp.zeros((B, H, bq, D), jnp.float32)
        d0 = jnp.zeros((B, H, bq), jnp.float32)
        (m, num, den), _ = jax.lax.scan(kv_step, (m0, n0, d0), jnp.arange(nk))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m))[..., None]
        return None, h.transpose(0, 2, 1, 3)  # (B,bq,H,D)

    _, hs = jax.lax.scan(q_step, None, jnp.arange(nq))
    h = hs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    return h.astype(q.dtype)


def mlstm_apply(
    params: Params,
    x: jax.Array,  # (B,S,d)
    *,
    n_heads: int,
    return_state: bool = False,
) -> Any:
    B, S, d = x.shape
    D = d // n_heads
    q = linear(x, params["wq"]).reshape(B, S, n_heads, D)
    k = linear(x, params["wk"]).reshape(B, S, n_heads, D)
    v = linear(x, params["wv"]).reshape(B, S, n_heads, D)
    i_raw, f_raw = _mlstm_gates(params, x)
    h = mlstm_parallel(q, k, v, i_raw, f_raw)
    z = jax.nn.silu(linear(x, params["wz"]).astype(jnp.float32)).astype(x.dtype)
    out = linear((h.reshape(B, S, d) * z), params["wo"])
    if not return_state:
        return out
    # Build the recurrent state equivalent to having consumed x_{0..S-1}
    # (used by prefill -> decode handoff): replay recurrently in one scan.
    state = mlstm_state_init(B, n_heads, D)
    _, state = mlstm_recurrent(params, x, state, n_heads=n_heads)
    return out, state


def mlstm_state_init(B: int, H: int, D: int) -> dict[str, jax.Array]:
    return {
        "C": jnp.zeros((B, H, D, D), jnp.float32),
        "n": jnp.zeros((B, H, D), jnp.float32),
        "m": jnp.full((B, H), 0.0, jnp.float32),
    }


def mlstm_recurrent(
    params: Params,
    x: jax.Array,  # (B,S,d) — S may be 1 (decode) or long (state build)
    state: dict[str, jax.Array],
    *,
    n_heads: int,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    B, S, d = x.shape
    D = d // n_heads
    scale = 1.0 / math.sqrt(D)
    q = (linear(x, params["wq"]) * scale).reshape(B, S, n_heads, D)
    k = linear(x, params["wk"]).reshape(B, S, n_heads, D)
    v = linear(x, params["wv"]).reshape(B, S, n_heads, D)
    i_raw, f_raw = _mlstm_gates(params, x)
    logf = jax.nn.log_sigmoid(f_raw)

    def step(carry, t):
        C, n, m = carry["C"], carry["n"], carry["m"]
        qt = q[:, t].astype(jnp.float32)  # (B,H,D)
        kt = k[:, t].astype(jnp.float32)
        vt = v[:, t].astype(jnp.float32)
        it, ft = i_raw[:, t], logf[:, t]  # (B,H)
        m_new = jnp.maximum(ft + m, it)
        fi = jnp.exp(ft + m - m_new)[..., None]
        ii = jnp.exp(it - m_new)[..., None]
        C_new = C * fi[..., None] + ii[..., None] * (
            vt[..., :, None] * kt[..., None, :]
        )  # (B,H,D,D) : v k^T
        n_new = n * fi + ii * kt
        num = jnp.einsum("bhij,bhj->bhi", C_new, qt)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhj,bhj->bh", n_new, qt)), jnp.exp(-m_new)
        )
        h = num / den[..., None]  # (B,H,D)
        return {"C": C_new, "n": n_new, "m": m_new}, h

    state, hs = jax.lax.scan(step, state, jnp.arange(S))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    z = jax.nn.silu(linear(x, params["wz"]).astype(jnp.float32)).astype(x.dtype)
    out = linear(h * z, params["wo"])
    return out, state


# --------------------------------------------------------------------- #
# sLSTM                                                                 #
# --------------------------------------------------------------------- #
def slstm_init(key, d_model: int, n_heads: int, dtype) -> Params:
    ks = jax.random.split(key, 6)
    D = d_model // n_heads
    # recurrent weights are block-diagonal per head: (H, D, D) per gate
    def rinit(k):
        return (
            jax.random.normal(k, (n_heads, D, D), jnp.float32) / math.sqrt(D)
        ).astype(dtype)

    kz, ki, kf, ko = jax.random.split(ks[0], 4)
    return {
        "w_in": dense_init(ks[1], d_model, 4 * d_model, dtype),  # z,i,f,o
        "r_z": rinit(kz),
        "r_i": rinit(ki),
        "r_f": rinit(kf),
        "r_o": rinit(ko),
        "bias": jnp.concatenate(
            [
                jnp.zeros((2 * d_model,), jnp.float32),
                jnp.full((d_model,), 3.0, jnp.float32),  # forget bias
                jnp.zeros((d_model,), jnp.float32),
            ]
        ),
        "wo": dense_init(ks[2], d_model, d_model, dtype),
    }


def slstm_state_init(B: int, H: int, D: int) -> dict[str, jax.Array]:
    z = jnp.zeros((B, H, D), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.zeros((B, H, D), jnp.float32)}


def slstm_apply(
    params: Params,
    x: jax.Array,  # (B,S,d)
    *,
    n_heads: int,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
) -> Any:
    B, S, d = x.shape
    H = n_heads
    D = d // H
    pre = (
        jnp.einsum(
            "bsd,df->bsf", x, params["w_in"], preferred_element_type=jnp.float32
        )
        + params["bias"]
    )  # (B,S,4d)
    pre = pre.reshape(B, S, 4, H, D)
    if state is None:
        state = slstm_state_init(B, H, D)

    r_z = params["r_z"].astype(jnp.float32)
    r_i = params["r_i"].astype(jnp.float32)
    r_f = params["r_f"].astype(jnp.float32)
    r_o = params["r_o"].astype(jnp.float32)

    def step(carry, t):
        c, n, h, m = carry["c"], carry["n"], carry["h"], carry["m"]
        rec = lambda r: jnp.einsum("bhj,hij->bhi", h, r)
        z_r = jnp.tanh(pre[:, t, 0] + rec(r_z))
        i_r = pre[:, t, 1] + rec(r_i)
        f_r = pre[:, t, 2] + rec(r_f)
        o_r = jax.nn.sigmoid(pre[:, t, 3] + rec(r_o))
        logf = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(logf + m, i_r)
        i_s = jnp.exp(i_r - m_new)
        f_s = jnp.exp(logf + m - m_new)
        c_new = f_s * c + i_s * z_r
        n_new = f_s * n + i_s
        h_new = o_r * c_new / jnp.maximum(n_new, 1e-6)
        return {"c": c_new, "n": n_new, "h": h_new, "m": m_new}, h_new

    state, hs = jax.lax.scan(step, state, jnp.arange(S))
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    out = linear(h, params["wo"])
    if return_state:
        return out, state
    return out
