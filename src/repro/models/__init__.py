"""Model substrate: composable decoder architectures in pure JAX."""
from repro.models.model import (
    ArchConfig,
    LayerSpec,
    cache_spec,
    decode_step,
    init_cache,
    init_params,
    model_flops_per_token,
    param_count,
    prefill,
    tiny_variant,
    train_loss,
)

__all__ = [
    "ArchConfig", "LayerSpec", "cache_spec", "decode_step", "init_cache",
    "init_params", "model_flops_per_token", "param_count", "prefill",
    "tiny_variant", "train_loss",
]
