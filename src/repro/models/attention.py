"""Attention: chunked online-softmax ("flash") in pure JAX + decode paths.

Three execution regimes:

* ``flash_attention`` — train/prefill, full (global) causal attention.
  Blocked over q and kv with a running (max, sum, acc) carry, so the
  (S, S) score matrix never materializes — same algorithm as the Pallas
  kernel in ``repro.kernels.flash_attention`` (which is the TPU-target
  twin; this is the XLA path used for dry-runs and as the oracle).
* ``local_attention`` — train/prefill, sliding-window attention computed
  block-locally: with block size = window, every query attends to its own
  block plus the previous one under an exact (g_q - g_k) < window mask.
  FLOPs are O(S * 2W) instead of O(S^2).
* ``decode_attention`` — single-token decode against a (possibly rolling)
  KV cache.

GQA throughout: H query heads grouped over KV heads (H = KV * G).
All softmax math in f32.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import Params, apply_rope, dense_init, linear, rms_norm

NEG_INF = -1e30


# --------------------------------------------------------------------- #
# parameter init                                                        #
# --------------------------------------------------------------------- #
def attention_init(
    key,
    d_model: int,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    dtype,
    qk_norm: bool = False,
) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(k1, d_model, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(k3, d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d_model, dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.zeros((head_dim,), jnp.float32)
        p["k_norm"] = jnp.zeros((head_dim,), jnp.float32)
    return p


# --------------------------------------------------------------------- #
# core blocked attention                                                #
# --------------------------------------------------------------------- #
def _gqa_scores(q, k):
    """q: (B, T, KV, G, D), k: (B, Skv, KV, D) -> (B, KV, G, T, Skv), f32."""
    return jnp.einsum(
        "btkgd,bskd->bkgts", q, k, preferred_element_type=jnp.float32
    )


def _gqa_out(p, v):
    """p: (B, KV, G, T, Skv) f32, v: (B, Skv, KV, D) -> (B, T, KV, G, D)."""
    return jnp.einsum(
        "bkgts,bskd->btkgd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )


def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,  # (B, S, KV, D)
    *,
    causal: bool = True,
    q_block: int = 512,
    kv_block: int = 512,
    scale: float | None = None,
) -> jax.Array:
    """Blocked online-softmax attention. Returns (B, S, H, D)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    q_block = min(q_block, S)
    kv_block = min(kv_block, S)
    S_orig = S
    lcm = q_block * kv_block // math.gcd(q_block, kv_block)
    if S % lcm:  # ragged tail: pad; padded keys are causally masked out
        pad = lcm - S % lcm
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    nq, nk = S // q_block, S // kv_block

    qb = (q * scale).reshape(B, nq, q_block, KV, G, D)
    kb = k.reshape(B, nk, kv_block, KV, D)
    vb = v.reshape(B, nk, kv_block, KV, D)

    q_pos = jnp.arange(S).reshape(nq, q_block)
    k_pos = jnp.arange(S).reshape(nk, kv_block)

    def q_step(_, qi):
        q_i = qb[:, qi]  # (B, bq, KV, G, D)
        qp = q_pos[qi]  # (bq,)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_i, v_i = kb[:, ki], vb[:, ki]
            s = _gqa_scores(q_i, k_i)  # (B, KV, G, bq, bk) f32
            if causal:
                mask = qp[:, None] >= k_pos[ki][None, :]  # (bq, bk)
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgts,bskd->bkgtd",
                p.astype(v_i.dtype),
                v_i,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, KV, G, q_block, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, bq, D)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, bq, KV, G, D)

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq, B, bq, KV, G, D)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, D)[:, :S_orig]
    return out.astype(q.dtype)


def local_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    q_block: int = 256,
    scale: float | None = None,
) -> jax.Array:
    """Exact causal sliding-window attention, banded-block formulation.

    Scans over q blocks; each q block attends only the `window//bq + 1`
    kv blocks that can fall inside its band, fetched with a clamped
    dynamic slice, under an exact (0 <= g_q - g_k < window) mask.
    FLOPs O(S * (window + bq)); peak score memory O(bq * (window + bq)).
    """
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    w = min(window, S)
    bq = min(q_block, w)
    if w % bq:
        bq = math.gcd(w, bq) or w
    S_orig = S
    if S % bq:  # ragged tail: pad; padded keys are causally masked out
        pad = bq - S % bq
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S += pad
    assert S % bq == 0 and w % bq == 0, (S, w, bq)
    nq = S // bq
    wb = w // bq  # kv blocks strictly before the diagonal that can matter
    span = (wb + 1) * bq  # keys visible to one q block (band + diagonal)

    qb = (q * scale).reshape(B, nq, bq, KV, G, D)
    kb = k.reshape(B, nq, bq, KV, D)
    vb = v.reshape(B, nq, bq, KV, D)

    def q_step(_, qi):
        q_i = qb[:, qi]  # (B, bq, KV, G, D)
        start = jnp.clip(qi - wb, 0, nq - (wb + 1))
        k_band = jax.lax.dynamic_slice_in_dim(kb, start, wb + 1, axis=1)
        v_band = jax.lax.dynamic_slice_in_dim(vb, start, wb + 1, axis=1)
        k_band = k_band.reshape(B, span, KV, D)
        v_band = v_band.reshape(B, span, KV, D)
        s = _gqa_scores(q_i, k_band)  # (B, KV, G, bq, span) f32
        q_pos = qi * bq + jnp.arange(bq)  # global query positions
        k_pos = start * bq + jnp.arange(span)
        delta = q_pos[:, None] - k_pos[None, :]
        mask = (delta >= 0) & (delta < w)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = _gqa_out(p, v_band)  # (B, bq, KV, G, D)
        return None, out

    _, outs = jax.lax.scan(q_step, None, jnp.arange(nq))  # (nq, B, bq, ...)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, D)[:, :S_orig]
    return out.astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, 1, H, D)
    k_cache: jax.Array,  # (B, Smax, KV, D)
    v_cache: jax.Array,
    *,
    valid_len: jax.Array | int,  # number of valid cache entries (rolling => Smax)
    scale: float | None = None,
) -> jax.Array:
    """One-token attention against a cache. Masks positions >= valid_len."""
    B, _, H, D = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, 1, KV, G, D)
    s = jnp.einsum(
        "btkgd,bskd->bkgts", qg, k_cache, preferred_element_type=jnp.float32
    )  # (B, KV, G, 1, Smax)
    Smax = k_cache.shape[1]
    valid = jnp.arange(Smax) < valid_len
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s.astype(jnp.float32), axis=-1)
    out = jnp.einsum(
        "bkgts,bskd->btkgd",
        p.astype(v_cache.dtype),
        v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, 1, H, D).astype(q.dtype)


# --------------------------------------------------------------------- #
# full attention layer (projections + rope + core)                      #
# --------------------------------------------------------------------- #
def repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(B, S, KV, D) -> (B, S, H, D): materialized GQA repeat, so every
    attention einsum runs with a model-axis-shardable head dimension."""
    B, S, KV, D = k.shape
    G = n_heads // KV
    return jnp.repeat(k, G, axis=2)


def attention_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: jax.Array,  # (B, S) or (S,)
    rope_theta: float,
    window: int | None = None,
    attn_impl: Any = None,  # pluggable kernel (e.g. pallas wrapper)
    q_block: int = 512,
    kv_block: int = 512,
    gqa_repeat: bool = False,
) -> jax.Array:
    B, S, d = x.shape
    q = linear(x, params["wq"]).reshape(B, S, n_heads, head_dim)
    k = linear(x, params["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = linear(x, params["wv"]).reshape(B, S, n_kv_heads, head_dim)
    if gqa_repeat and n_kv_heads < n_heads:
        k = repeat_kv(k, n_heads)
        v = repeat_kv(v, n_heads)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if positions.ndim == 1:
        positions = positions[None, :]
    if rope_theta:  # theta == 0 => no positional encoding (e.g. jamba)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    if attn_impl is not None:
        out = attn_impl(q, k, v, window=window)
    elif window is not None and window < S:
        out = local_attention(q, k, v, window=window)
    else:
        out = flash_attention(q, k, v, causal=True, q_block=q_block, kv_block=kv_block)
    return linear(out.reshape(B, S, n_heads * head_dim), params["wo"])


def attention_prefill(
    params: Params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: jax.Array,
    rope_theta: float,
    window: int | None,
    cache_len: int,
    gqa_repeat: bool = False,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Prefill: same as apply, but also returns the KV cache (already laid
    out for decode: rolling if windowed, padded to cache_len otherwise —
    always in KV-head layout; gqa_repeat affects compute only)."""
    B, S, d = x.shape
    out = attention_apply(
        params,
        x,
        n_heads=n_heads,
        n_kv_heads=n_kv_heads,
        head_dim=head_dim,
        positions=positions,
        rope_theta=rope_theta,
        window=window,
        gqa_repeat=gqa_repeat,
    )
    k = linear(x, params["wk"]).reshape(B, S, n_kv_heads, head_dim)
    v = linear(x, params["wv"]).reshape(B, S, n_kv_heads, head_dim)
    if "k_norm" in params:
        k = rms_norm(k, params["k_norm"])
    if positions.ndim == 1:
        positions = positions[None, :]
    if rope_theta:
        k = apply_rope(k, positions, rope_theta)
    eff = min(window, cache_len) if window is not None else cache_len
    if S >= eff:
        k_c, v_c = k[:, S - eff :], v[:, S - eff :]
    else:
        pad = ((0, 0), (0, eff - S), (0, 0), (0, 0))
        k_c, v_c = jnp.pad(k, pad), jnp.pad(v, pad)
    return out, {"k": k_c, "v": v_c}


def attention_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    cache: dict[str, jax.Array],
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    position: jax.Array,  # scalar int32 — absolute position of the new token
    rope_theta: float,
    window: int | None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """One decode step: write the new KV at the right slot (rolling for
    windowed layers), attend, project."""
    B, _, d = x.shape
    q = linear(x, params["wq"]).reshape(B, 1, n_heads, head_dim)
    k = linear(x, params["wk"]).reshape(B, 1, n_kv_heads, head_dim)
    v = linear(x, params["wv"]).reshape(B, 1, n_kv_heads, head_dim)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if rope_theta:
        pos_b = jnp.full((B, 1), position, jnp.int32)
        q = apply_rope(q, pos_b, rope_theta)
        k = apply_rope(k, pos_b, rope_theta)

    k_cache, v_cache = cache["k"], cache["v"]
    Smax = k_cache.shape[1]
    slot = position % Smax if window is not None else position
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    valid = jnp.minimum(position + 1, Smax)
    out = decode_attention(q, k_cache, v_cache, valid_len=valid)
    y = linear(out.reshape(B, 1, n_heads * head_dim), params["wo"])
    return y, {"k": k_cache, "v": v_cache}


def reference_attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *, causal=True, window=None, scale=None
) -> jax.Array:
    """O(S^2)-memory oracle used by tests (materializes the score matrix)."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = (q * scale).reshape(B, S, KV, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k, preferred_element_type=jnp.float32)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i >= j
    if window is not None:
        mask &= (i - j) < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bkgts,bskd->btkgd", p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    return out.reshape(B, S, H, D).astype(q.dtype)
