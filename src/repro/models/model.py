"""Composable decoder model built from an ArchConfig.

An architecture is a sequence of *groups*; each group is a repeating
*pattern* of LayerSpecs (mixer kind + attention window + FFN kind). Layer
parameters inside a group are stacked on a leading `repeats` axis and the
group is applied with ``lax.scan`` — the HLO stays one-pattern-sized no
matter how deep the model is (essential for 72-layer 398B dry-runs), and
it is also the production choice (compile time, code size).

Heterogeneous stacks come for free: jamba's 1-attention-per-8 pattern or
gemma3's 5-local:1-global schedule are just patterns; positions inside a
pattern may carry different mixers with different cache pytrees.

Three entry points:
  * ``train_loss``  — tokens/embeds -> mean NLL (chunked CE; logits never
    materialize at (B, S, V)),
  * ``prefill``     — consume a prompt, return last-position logits + the
    decode cache,
  * ``decode_step`` — one token against the cache.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import moe as moe_mod
from repro.models import xlstm as xl
from repro.sharding.act import constrain_batch
from repro.models.layers import (
    Params,
    chunked_softmax_xent,
    dense_init,
    embed,
    embed_init,
    logits_for_last,
    rms_norm,
    rms_norm_init,
)


# --------------------------------------------------------------------- #
# configuration                                                         #
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"  # attn | mamba | mlstm | slstm
    window: int | None = None  # sliding-window size for attn
    ffn: str = "dense"  # dense | moe | none


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    #: ((pattern, repeats), ...) — sum(len(p)*r) == n_layers
    groups: tuple[tuple[tuple[LayerSpec, ...], int], ...]
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_capacity_factor: float = 1.25
    mamba_d_state: int = 16
    mamba_expand: int = 2
    mamba_conv: int = 4
    #: dtype of the discretized SSM scan operands (Abar, Bx). bf16 halves
    #: the dominant train-memory bytes for hybrid archs (hillclimb H3);
    #: the state carry stays f32 at chunk boundaries.
    mamba_scan_dtype: str = "float32"
    n_codebooks: int = 1  # musicgen: 4 parallel heads
    frontend: str | None = None  # None | "vit_stub" | "encodec_stub"
    n_patches: int = 0  # vlm: patch embeddings prepended
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d)
    param_dtype: str = "bfloat16"
    loss_chunk: int = 512
    mamba_chunk: int = 128
    attn_q_block: int = 512
    attn_kv_block: int = 512
    vocab_pad_multiple: int = 128
    #: GQA layout: False = grouped (KV,G,D) einsums (paper-faithful
    #: baseline); True = repeat KV heads to H before attention so the
    #: head dim shards cleanly on the model axis (hillclimb H1 — kills
    #: the reshape resharding all-gathers; see EXPERIMENTS.md §Perf).
    gqa_repeat: bool = False
    source: str = ""  # provenance note

    @property
    def n_layers(self) -> int:
        return sum(len(p) * r for p, r in self.groups)

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up so the model axis can shard it (see planner)."""
        from repro.models.layers import pad_to_multiple

        return pad_to_multiple(self.vocab_size, self.vocab_pad_multiple)

    @property
    def uses_embedding_input(self) -> bool:
        """Frontend-stub archs feed embeddings, not token ids."""
        return self.frontend == "encodec_stub"

    def layer_specs(self) -> list[LayerSpec]:
        out: list[LayerSpec] = []
        for pattern, repeats in self.groups:
            out.extend(list(pattern) * repeats)
        return out


def tiny_variant(cfg: ArchConfig, **overrides) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests: shrink width/depth/
    experts/vocab while keeping the layer-pattern structure."""
    shrunk_groups = tuple(
        (pattern, min(repeats, 1)) for pattern, repeats in cfg.groups
    )
    base = dataclasses.replace(
        cfg,
        name=cfg.name + "-tiny",
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        groups=shrunk_groups,
        moe_experts=min(cfg.moe_experts, 4),
        moe_top_k=min(cfg.moe_top_k, 2),
        moe_capacity_factor=4.0,  # ample: decode-vs-prefill tests are exact
        n_patches=min(cfg.n_patches, 8),
        loss_chunk=64,
        mamba_chunk=16,
        attn_q_block=32,
        attn_kv_block=32,
        param_dtype="float32",
    )
    return dataclasses.replace(base, **overrides)


# --------------------------------------------------------------------- #
# parameter init                                                        #
# --------------------------------------------------------------------- #
def _layer_init(key, spec: LayerSpec, cfg: ArchConfig) -> Params:
    km, kf = jax.random.split(key)
    p: Params = {"norm1": rms_norm_init(cfg.d_model)}
    if spec.mixer == "attn":
        p["mixer"] = attn.attention_init(
            km,
            cfg.d_model,
            cfg.n_heads,
            cfg.n_kv_heads,
            cfg.head_dim,
            cfg.dtype,
            qk_norm=cfg.qk_norm,
        )
    elif spec.mixer == "mamba":
        p["mixer"] = mam.mamba_init(
            km,
            cfg.d_model,
            expand=cfg.mamba_expand,
            d_state=cfg.mamba_d_state,
            d_conv=cfg.mamba_conv,
            dtype=cfg.dtype,
        )
    elif spec.mixer == "mlstm":
        p["mixer"] = xl.mlstm_init(km, cfg.d_model, cfg.n_heads, cfg.dtype)
    elif spec.mixer == "slstm":
        p["mixer"] = xl.slstm_init(km, cfg.d_model, cfg.n_heads, cfg.dtype)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    if spec.ffn != "none":
        p["norm2"] = rms_norm_init(cfg.d_model)
        if spec.ffn == "dense":
            from repro.models.layers import swiglu_init

            p["ffn"] = swiglu_init(kf, cfg.d_model, cfg.d_ff, cfg.dtype)
        elif spec.ffn == "moe":
            p["ffn"] = moe_mod.moe_init(
                kf, cfg.d_model, cfg.d_ff, cfg.moe_experts, cfg.dtype
            )
        else:  # pragma: no cover
            raise ValueError(spec.ffn)
    return p


def init_params(cfg: ArchConfig, key: jax.Array) -> Params:
    keys = jax.random.split(key, len(cfg.groups) + 3)
    groups = []
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        pat_keys = jax.random.split(keys[gi], repeats * len(pattern)).reshape(
            repeats, len(pattern), 2
        )
        group_params = {}
        for i, spec in enumerate(pattern):
            group_params[str(i)] = jax.vmap(
                lambda k, s=spec: _layer_init(k, s, cfg)
            )(pat_keys[:, i])
        groups.append(group_params)
    kp, ke, kh = keys[-3], keys[-2], keys[-1]
    params: Params = {
        "groups": groups,
        "final_norm": rms_norm_init(cfg.d_model),
    }
    V = cfg.padded_vocab
    if cfg.uses_embedding_input:
        params["lm_head"] = dense_init(
            kh, cfg.d_model, cfg.n_codebooks * V, cfg.dtype
        )
    else:
        params["embed"] = embed_init(ke, V, cfg.d_model, cfg.dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = dense_init(kh, cfg.d_model, V, cfg.dtype)
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# --------------------------------------------------------------------- #
# layer application                                                     #
# --------------------------------------------------------------------- #
def _mixer_train(p, spec: LayerSpec, h, cfg: ArchConfig, positions):
    if spec.mixer == "attn":
        return attn.attention_apply(
            p,
            h,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            positions=positions,
            rope_theta=cfg.rope_theta,
            window=spec.window,
            q_block=cfg.attn_q_block,
            kv_block=cfg.attn_kv_block,
            gqa_repeat=cfg.gqa_repeat,
        )
    if spec.mixer == "mamba":
        return mam.mamba_apply(
            p, h, d_state=cfg.mamba_d_state, chunk=cfg.mamba_chunk,
            scan_dtype=cfg.mamba_scan_dtype,
        )
    if spec.mixer == "mlstm":
        return xl.mlstm_apply(p, h, n_heads=cfg.n_heads)
    if spec.mixer == "slstm":
        return xl.slstm_apply(p, h, n_heads=cfg.n_heads)
    raise ValueError(spec.mixer)  # pragma: no cover


def _ffn_train(p, spec: LayerSpec, h, cfg: ArchConfig):
    """Returns (y, aux_loss_scalar)."""
    if spec.ffn == "dense":
        from repro.models.layers import swiglu

        return swiglu(p, h), jnp.float32(0.0)
    y, aux = moe_mod.moe_apply(
        p,
        h,
        top_k=cfg.moe_top_k,
        capacity_factor=cfg.moe_capacity_factor,
        return_aux=True,
    )
    return y, aux.get("load_balance_loss", jnp.float32(0.0))


def _layer_train(p, spec: LayerSpec, x, cfg: ArchConfig, positions):
    h = rms_norm(x, p["norm1"])
    x = constrain_batch(x + _mixer_train(p["mixer"], spec, h, cfg, positions))
    aux = jnp.float32(0.0)
    if spec.ffn != "none":
        h = rms_norm(x, p["norm2"])
        y, aux = _ffn_train(p["ffn"], spec, h, cfg)
        x = constrain_batch(x + y)
    return x, aux


def _backbone_train(params, cfg: ArchConfig, x, positions):
    """Apply all groups with scan-over-repeats + remat per pattern block."""
    aux_total = jnp.float32(0.0)

    for gi, (pattern, repeats) in enumerate(cfg.groups):
        gp = params["groups"][gi]

        @jax.checkpoint
        def block(x, layer_stack, pattern=pattern):
            aux = jnp.float32(0.0)
            for i, spec in enumerate(pattern):
                x, a = _layer_train(layer_stack[str(i)], spec, x, cfg, positions)
                aux = aux + a
            return x, aux

        def scan_body(carry, layer_stack, block=block):
            x, aux = carry
            x, a = block(x, layer_stack)
            return (x, aux + a), None

        (x, aux_total), _ = jax.lax.scan(
            scan_body, (x, aux_total), gp, length=repeats
        )
    return x, aux_total


# --------------------------------------------------------------------- #
# train loss                                                            #
# --------------------------------------------------------------------- #
def _input_hidden(params, cfg: ArchConfig, batch) -> tuple[jax.Array, jax.Array]:
    """Returns (hidden (B,S,d), positions (S,))."""
    if cfg.uses_embedding_input:  # musicgen: precomputed frame embeddings
        x = batch["frame_embeds"].astype(cfg.dtype)
    elif cfg.frontend == "vit_stub":  # internvl: patches ++ text tokens
        patches = batch["patch_embeds"].astype(cfg.dtype)  # (B,P,d)
        text = embed(params["embed"], batch["tokens"])  # (B,S-P,d)
        x = jnp.concatenate([patches, text], axis=1)
    else:
        x = embed(params["embed"], batch["tokens"])
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    x = constrain_batch(x)
    S = x.shape[1]
    return x, jnp.arange(S)


def _unembed_weight(params, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def train_loss(
    params: Params, cfg: ArchConfig, batch: dict[str, jax.Array]
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """batch: tokens/frame_embeds/patch_embeds + labels.
    labels: (B, S) int32, or (B, S, K) for multi-codebook archs; -1 masks."""
    x, positions = _input_hidden(params, cfg, batch)
    x, aux = _backbone_train(params, cfg, x, positions)
    x = rms_norm(x, params["final_norm"])
    w = _unembed_weight(params, cfg)
    labels = batch["labels"]
    if cfg.n_codebooks > 1:
        V = cfg.padded_vocab
        losses = []
        for cb in range(cfg.n_codebooks):
            losses.append(
                chunked_softmax_xent(
                    x,
                    w[:, cb * V : (cb + 1) * V],
                    labels[..., cb],
                    cfg.loss_chunk,
                    valid_vocab=cfg.vocab_size,
                )
            )
        nll = jnp.mean(jnp.stack(losses))
    else:
        nll = chunked_softmax_xent(
            x, w, labels, cfg.loss_chunk, valid_vocab=cfg.vocab_size
        )
    aux_scaled = 0.01 * aux / max(1, cfg.n_layers)
    metrics = {"nll": nll, "moe_aux": aux}
    return nll + aux_scaled, metrics


# --------------------------------------------------------------------- #
# caches                                                                #
# --------------------------------------------------------------------- #
def _mixer_cache_spec(
    spec: LayerSpec, cfg: ArchConfig, B: int, cache_len: int
) -> dict[str, tuple[tuple[int, ...], Any]]:
    """(shape, dtype) per cache leaf for ONE layer (unstacked)."""
    if spec.mixer == "attn":
        eff = min(spec.window, cache_len) if spec.window else cache_len
        kv = (B, eff, cfg.n_kv_heads, cfg.head_dim)
        return {"k": (kv, cfg.dtype), "v": (kv, cfg.dtype)}
    if spec.mixer == "mamba":
        inner = cfg.mamba_expand * cfg.d_model
        return {
            "ssm": ((B, inner, cfg.mamba_d_state), jnp.float32),
            "conv": ((B, cfg.mamba_conv - 1, inner), cfg.dtype),
        }
    if spec.mixer == "mlstm":
        D = cfg.d_model // cfg.n_heads
        return {
            "C": ((B, cfg.n_heads, D, D), jnp.float32),
            "n": ((B, cfg.n_heads, D), jnp.float32),
            "m": ((B, cfg.n_heads), jnp.float32),
        }
    if spec.mixer == "slstm":
        D = cfg.d_model // cfg.n_heads
        s = ((B, cfg.n_heads, D), jnp.float32)
        return {"c": s, "n": s, "h": s, "m": s}
    raise ValueError(spec.mixer)  # pragma: no cover


def cache_spec(
    cfg: ArchConfig, batch_size: int, cache_len: int
) -> dict[str, Any]:
    """ShapeDtypeStruct pytree for the decode cache (dry-run input)."""
    groups = []
    for pattern, repeats in cfg.groups:
        g = {}
        for i, spec in enumerate(pattern):
            leaves = _mixer_cache_spec(spec, cfg, batch_size, cache_len)
            g[str(i)] = {
                k: jax.ShapeDtypeStruct((repeats, *shape), dt)
                for k, (shape, dt) in leaves.items()
            }
        groups.append(g)
    return {
        "groups": groups,
        "position": jax.ShapeDtypeStruct((), jnp.int32),
    }


def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int):
    spec = cache_spec(cfg, batch_size, cache_len)
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype)
        if s.dtype != jnp.int32
        else jnp.zeros(s.shape, s.dtype),
        spec,
        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct),
    )


# --------------------------------------------------------------------- #
# prefill                                                               #
# --------------------------------------------------------------------- #
def _layer_prefill(p, spec: LayerSpec, x, cfg: ArchConfig, positions, cache_len):
    h = rms_norm(x, p["norm1"])
    if spec.mixer == "attn":
        y, c = attn.attention_prefill(
            p["mixer"],
            h,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            positions=positions,
            rope_theta=cfg.rope_theta,
            window=spec.window,
            cache_len=cache_len,
            gqa_repeat=cfg.gqa_repeat,
        )
    elif spec.mixer == "mamba":
        y, c = mam.mamba_apply(
            p["mixer"],
            h,
            d_state=cfg.mamba_d_state,
            chunk=cfg.mamba_chunk,
            return_state=True,
        )
    elif spec.mixer == "mlstm":
        y, c = xl.mlstm_apply(p["mixer"], h, n_heads=cfg.n_heads, return_state=True)
    elif spec.mixer == "slstm":
        y, c = xl.slstm_apply(p["mixer"], h, n_heads=cfg.n_heads, return_state=True)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    x = x + y
    if spec.ffn != "none":
        h = rms_norm(x, p["norm2"])
        y, _ = _ffn_train(p["ffn"], spec, h, cfg)
        x = x + y
    return x, c


def prefill(
    params: Params, cfg: ArchConfig, batch: dict[str, jax.Array], cache_len: int
) -> tuple[jax.Array, Any]:
    """Consume the prompt; return (last-token logits, decode cache)."""
    x, positions = _input_hidden(params, cfg, batch)
    B, S, _ = x.shape
    cache_groups = []
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        gp = params["groups"][gi]

        @jax.checkpoint
        def block(x, layer_stack, pattern=pattern):
            caches = {}
            for i, spec in enumerate(pattern):
                x, c = _layer_prefill(
                    layer_stack[str(i)], spec, x, cfg, positions, cache_len
                )
                caches[str(i)] = c
            return x, caches

        def scan_body(x, layer_stack, block=block):
            return block(x, layer_stack)

        x, caches = jax.lax.scan(scan_body, x, gp, length=repeats)
        cache_groups.append(caches)
    x = rms_norm(x, params["final_norm"])
    w = _unembed_weight(params, cfg)
    logits = logits_for_last(x[:, -1:], w, valid_vocab=cfg.vocab_size)
    if cfg.n_codebooks > 1:
        logits = logits.reshape(B, 1, cfg.n_codebooks, cfg.padded_vocab)
    cache = {"groups": cache_groups, "position": jnp.asarray(S, jnp.int32)}
    return logits, cache


# --------------------------------------------------------------------- #
# decode                                                                #
# --------------------------------------------------------------------- #
def _layer_decode(p, spec: LayerSpec, x, c, cfg: ArchConfig, position):
    h = rms_norm(x, p["norm1"])
    if spec.mixer == "attn":
        y, c = attn.attention_decode(
            p["mixer"],
            h,
            c,
            n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.head_dim,
            position=position,
            rope_theta=cfg.rope_theta,
            window=spec.window,
        )
    elif spec.mixer == "mamba":
        y, c = mam.mamba_decode(p["mixer"], h, c, d_state=cfg.mamba_d_state)
    elif spec.mixer == "mlstm":
        y, c = xl.mlstm_recurrent(p["mixer"], h, c, n_heads=cfg.n_heads)
    elif spec.mixer == "slstm":
        y, c = xl.slstm_apply(
            p["mixer"], h, n_heads=cfg.n_heads, state=c, return_state=True
        )
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    x = x + y
    if spec.ffn != "none":
        h = rms_norm(x, p["norm2"])
        y, _ = _ffn_train(p["ffn"], spec, h, cfg)
        x = x + y
    return x, c


def decode_step(
    params: Params, cfg: ArchConfig, batch: dict[str, jax.Array], cache: Any
) -> tuple[jax.Array, Any]:
    """One token for every sequence in the batch. batch: {"tokens": (B,1)}
    or {"frame_embeds": (B,1,d)}. Returns (logits, new cache)."""
    position = cache["position"]
    if cfg.uses_embedding_input:
        x = batch["frame_embeds"].astype(cfg.dtype)
    else:
        x = embed(params["embed"], batch["tokens"])
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cfg.dtype)
    B = x.shape[0]

    new_groups = []
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        gp = params["groups"][gi]
        gc = cache["groups"][gi]

        def scan_body(x, stacks, pattern=pattern):
            layer_stack, cache_stack = stacks
            new_caches = {}
            for i, spec in enumerate(pattern):
                x, c = _layer_decode(
                    layer_stack[str(i)], spec, x, cache_stack[str(i)], cfg, position
                )
                new_caches[str(i)] = c
            return x, new_caches

        x, new_caches = jax.lax.scan(scan_body, x, (gp, gc), length=repeats)
        new_groups.append(new_caches)

    x = rms_norm(x, params["final_norm"])
    w = _unembed_weight(params, cfg)
    logits = logits_for_last(x, w, valid_vocab=cfg.vocab_size)
    if cfg.n_codebooks > 1:
        logits = logits.reshape(B, 1, cfg.n_codebooks, cfg.padded_vocab)
    new_cache = {"groups": new_groups, "position": position + 1}
    return logits, new_cache


# --------------------------------------------------------------------- #
# accounting                                                            #
# --------------------------------------------------------------------- #
def active_param_count(cfg: ArchConfig, params: Params) -> int:
    """Parameters touched per token (MoE: top_k of E experts)."""
    total = param_count(params)
    if cfg.moe_experts and cfg.moe_top_k:
        expert_leaves = 0
        for gi, (pattern, repeats) in enumerate(cfg.groups):
            for i, spec in enumerate(pattern):
                if spec.ffn == "moe":
                    ffn = params["groups"][gi][str(i)]["ffn"]
                    for name in ("w_gate", "w_up", "w_down"):
                        expert_leaves += ffn[name].size
        inactive = expert_leaves * (1 - cfg.moe_top_k / cfg.moe_experts)
        return int(total - inactive)
    return total


def model_flops_per_token(cfg: ArchConfig, params: Params) -> float:
    """The 6N approximation (training); N = active params."""
    return 6.0 * active_param_count(cfg, params)
