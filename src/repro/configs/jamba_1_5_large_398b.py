"""Jamba-1.5-Large (398B total / ~94B active) [arXiv:2403.19887 + 2408.12570; hf].

Hybrid Mamba+attention, 1:7 attention:mamba per 8-layer Jamba block with
the attention layer at in-block index 4 (paper Fig. 2); MoE (16 experts,
top-2) replaces the MLP on every *other* layer (e=2). No positional
encoding on attention layers (rope_theta=0) — Mamba carries position.
"""
from repro.models.model import ArchConfig, LayerSpec

_M = LayerSpec(mixer="mamba", ffn="dense")
_M_MOE = LayerSpec(mixer="mamba", ffn="moe")
_A = LayerSpec(mixer="attn", ffn="dense")

# in-block index:    0     1      2     3      4   5      6     7
_PATTERN = (_M, _M_MOE, _M, _M_MOE, _A, _M_MOE, _M, _M_MOE)

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    groups=((_PATTERN, 9),),  # 72 layers
    rope_theta=0.0,  # Jamba uses no explicit positional encoding
    moe_experts=16,
    moe_top_k=2,
    mamba_d_state=16,
    mamba_expand=2,
    mamba_conv=4,
    source="arXiv:2403.19887 (Jamba), 2408.12570 (Jamba-1.5); hf",
)
