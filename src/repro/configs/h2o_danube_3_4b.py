"""H2O-Danube3-4B [arXiv:2401.16818 (danube series); unverified].

llama+mistral mix with sliding-window attention (window 4096).
head_dim = 3840/32 = 120 (not 128-aligned; the planner therefore never
shards head_dim).
"""
from repro.models.model import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    head_dim=120,
    d_ff=10240,
    vocab_size=32000,
    groups=(((LayerSpec(window=4096),), 24),),
    rope_theta=10_000.0,
    source="arXiv:2401.16818; unverified",
)
