"""Mixtral 8x22B [arXiv:2401.04088; hf]. 8-expert top-2 MoE every layer;
sliding-window attention per the assignment listing (window 4096)."""
from repro.models.model import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="mixtral-8x22b",
    family="moe",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    groups=(((LayerSpec(window=4096, ffn="moe"),), 56),),
    rope_theta=1_000_000.0,
    moe_experts=8,
    moe_top_k=2,
    source="arXiv:2401.04088; hf",
)
