"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config;
``get_tiny(name)`` returns the reduced same-family smoke-test variant.
"""
from __future__ import annotations

import importlib

from repro.models.model import ArchConfig, tiny_variant

ARCH_IDS = [
    "jamba-1.5-large-398b",
    "gemma3-1b",
    "granite-8b",
    "qwen3-4b",
    "h2o-danube-3-4b",
    "mixtral-8x22b",
    "granite-moe-1b-a400m",
    "internvl2-26b",
    "xlstm-1.3b",
    "musicgen-large",
]

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(name: str) -> ArchConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCH_IDS}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_tiny(name: str) -> ArchConfig:
    mod = importlib.import_module(_MODULES[name])
    if hasattr(mod, "TINY"):
        return mod.TINY
    return tiny_variant(mod.CONFIG)


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
