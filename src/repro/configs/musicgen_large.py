"""MusicGen-Large decoder [arXiv:2306.05284; hf] — decoder-only transformer
over EnCodec tokens, 4 codebooks x 2048 vocab with the delay pattern.

The EnCodec frontend is a STUB per the assignment: input_specs() supplies
precomputed summed codebook frame embeddings (B, S, d); the model carries
4 parallel output heads (one per codebook). MHA (kv heads = heads = 32).
SwiGLU is used for the FFN (documented deviation from the GELU MLP).
"""
from repro.models.model import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    groups=(((LayerSpec(),), 48),),
    rope_theta=10_000.0,
    n_codebooks=4,
    frontend="encodec_stub",
    source="arXiv:2306.05284; hf",
)
