"""Gemma 3 1B [hf:google/gemma-3-1b-pt; unverified].

5 local (sliding window 512) : 1 global attention schedule; 26 layers =
4 full (5L+1G) units + 2 trailing local layers. head_dim 256 (4 heads on
d_model 1152 — q/o project 1152->1024). qk-RMSNorm, tied embeddings,
sqrt(d) embedding scaling, 262k vocab. rope_theta 1e6 (global layers'
value; the 10k local theta is a documented simplification).
"""
from repro.models.model import ArchConfig, LayerSpec

_L = LayerSpec(mixer="attn", window=512, ffn="dense")
_G = LayerSpec(mixer="attn", window=None, ffn="dense")

CONFIG = ArchConfig(
    name="gemma3-1b",
    family="dense",
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    groups=(((_L, _L, _L, _L, _L, _G), 4), ((_L, _L), 1)),  # 26 layers
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    embed_scale=True,
    source="hf:google/gemma-3-1b-pt; unverified",
)
