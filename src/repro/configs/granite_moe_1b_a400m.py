"""Granite 3.0 1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
32-expert top-8 fine-grained MoE (d_ff 512 per expert). Vocab 49155 is
padded to 49280 (multiple of 128) by the model; logits beyond 49155 are
masked in the loss."""
from repro.models.model import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    groups=(((LayerSpec(ffn="moe"),), 24),),
    rope_theta=10_000.0,
    moe_experts=32,
    moe_top_k=8,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)
