"""xLSTM-1.3B [arXiv:2405.04517; unverified]. xLSTM[7:1]: 7 mLSTM blocks
per 1 sLSTM block (sLSTM at in-block index 7). d_ff=0: cells carry their
own projections; no separate FFN sublayer (see DESIGN.md width note)."""
from repro.models.model import ArchConfig, LayerSpec

_M = LayerSpec(mixer="mlstm", ffn="none")
_S = LayerSpec(mixer="slstm", ffn="none")

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    head_dim=512,
    d_ff=0,
    vocab_size=50304,
    groups=(((_M, _M, _M, _M, _M, _M, _M, _S), 6),),  # 48 layers
    rope_theta=0.0,  # recurrent cells encode position
    source="arXiv:2405.04517; unverified",
)
