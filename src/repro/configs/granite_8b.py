"""IBM Granite 8B (code) [arXiv:2405.04324; hf]. Plain llama-style GQA."""
from repro.models.model import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="granite-8b",
    family="dense",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
    groups=(((LayerSpec(),), 36),),
    rope_theta=10_000_000.0,  # granite-code long-context theta
    source="arXiv:2405.04324; hf",
)
