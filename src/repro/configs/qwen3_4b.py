"""Qwen3-4B [hf:Qwen/Qwen3-8B family card; hf]. GQA + qk-RMSNorm."""
from repro.models.model import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    groups=(((LayerSpec(),), 36),),
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:Qwen/Qwen3-4B; hf",
)
