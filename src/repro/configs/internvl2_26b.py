"""InternVL2-26B [arXiv:2404.16821; hf] — InternLM2-20B language backbone.

The InternViT-6B vision frontend is a STUB per the assignment:
input_specs() supplies 256 precomputed patch embeddings (one 448px tile
after pixel-shuffle) prepended to the text tokens; patch positions are
masked out of the loss.
"""
from repro.models.model import ArchConfig, LayerSpec

CONFIG = ArchConfig(
    name="internvl2-26b",
    family="vlm",
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,  # padded to 92672 internally
    groups=(((LayerSpec(),), 48),),
    rope_theta=1_000_000.0,
    frontend="vit_stub",
    n_patches=256,
    source="arXiv:2404.16821; hf",
)
