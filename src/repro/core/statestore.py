"""Centralized, versioned application-state store.

Plays the role MongoDB plays in the paper (§3.2.1): the single source of
truth that stateless servers read/modify per request. We reproduce the
properties the paper *relies on* rather than the wire protocol:

* per-client **logical clocks** (Lamport-style revision counters, §4.2.1):
  every mutation that affects a client increments that client's clock;
* **multi-document transactions** (§3.2.1 "distributed transactions are
  essential to the integrity of the platform"): `transaction()` applies a
  batch of mutations atomically — observers never see a torn write;
* **idempotent result ingestion**: results are keyed (task_id, seq) so
  retries after lost acks (the paper's intermittent-connectivity case)
  cannot duplicate data;
* **immutability** of payload/parameter documents → safe client caching.

The store is deliberately process-local; `repro.core.server` keeps the
server tier stateless exactly as the paper prescribes, so pointing it at a
real MongoDB is an I/O swap, not a redesign.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, replace
from typing import Any, Callable, Iterable

from repro.core.columns import FleetColumns
from repro.core.documents import (
    Assignment,
    InvalidTransition,
    Parameters,
    Payload,
    Result,
    Task,
    TaskStatus,
)


class DocumentExists(Exception):
    pass


class NoSuchDocument(Exception):
    pass


class StaleWrite(Exception):
    """Optimistic-concurrency failure inside a transaction."""


class ClientRecord:
    """Per-client registry row. Slim slotted layout: when the store is
    attached to a `FleetColumns` arena the logical clock and online flag
    live in the shared numpy columns (one int64/bool per client fleet-wide
    instead of a dict slot per object); detached records (unit tests, bare
    stores) fall back to local scalars. Either way the attribute API is
    unchanged — `rec.logical_clock += 1` works identically."""

    __slots__ = ("client_id", "metadata", "_cols", "_row", "_clock", "_online")

    def __init__(
        self,
        client_id: str,
        logical_clock: int = 0,
        online: bool = True,
        metadata: dict[str, Any] | None = None,
    ):
        self.client_id = client_id
        self.metadata = {} if metadata is None else metadata
        self._cols: FleetColumns | None = None
        self._row = -1
        self._clock = int(logical_clock)
        self._online = bool(online)

    def bind(self, cols: FleetColumns) -> None:
        """Move this record's scalars into the shared arena."""
        row = cols.row_for(self.client_id)
        cols.clock[row] = self._clock
        cols.online[row] = self._online
        self._cols, self._row = cols, row

    @property
    def logical_clock(self) -> int:
        if self._cols is not None:
            return int(self._cols.clock[self._row])
        return self._clock

    @logical_clock.setter
    def logical_clock(self, value: int) -> None:
        if self._cols is not None:
            self._cols.clock[self._row] = value
        else:
            self._clock = int(value)

    @property
    def online(self) -> bool:
        if self._cols is not None:
            return bool(self._cols.online[self._row])
        return self._online

    @online.setter
    def online(self, value: bool) -> None:
        if self._cols is not None:
            self._cols.online[self._row] = value
        else:
            self._online = bool(value)

    def __repr__(self) -> str:  # debugging parity with the old dataclass
        return (
            f"ClientRecord(client_id={self.client_id!r}, "
            f"logical_clock={self.logical_clock}, online={self.online}, "
            f"metadata={self.metadata!r})"
        )


class StateStore:
    """Thread-safe in-memory document store with per-client logical clocks.

    A single lock guards each transaction — the in-process stand-in for
    MongoDB's multi-document ACID transactions. All public mutators go
    through `transaction()` so the atomicity claim is structural, not
    conventional.
    """

    def __init__(self) -> None:
        self._lock = threading.RLock()
        #: optional shared columnar arena for per-client scalars
        self._columns: FleetColumns | None = None
        self._payloads: dict[str, Payload] = {}
        self._parameters: dict[str, Parameters] = {}
        self._tasks: dict[str, Task] = {}
        #: client_id -> ids of that client's possibly-ACTIVE tasks. Kept so
        #: `fetch_state` is O(client's tasks), not O(all tasks ever) — the
        #: difference between O(fleet) and O(fleet^2) per simulated round.
        #: Pruned lazily when a listed task turns out terminal.
        self._active_by_client: dict[str, list[str]] = {}
        self._assignments: dict[str, Assignment] = {}
        self._results: dict[str, list[Result]] = {}  # task_id -> dense list
        self._clients: dict[str, ClientRecord] = {}
        self._watchers: list[Callable[[str, int], None]] = []

    # ------------------------------------------------------------------ #
    # transactions                                                       #
    # ------------------------------------------------------------------ #
    def transaction(self, fn: Callable[["StateStore"], Any]) -> Any:
        """Run `fn(store)` atomically. Mutations inside `fn` must use the
        underscore-free helpers below. On exception nothing is observed
        half-applied (helpers mutate only after validation; the lock keeps
        readers out for the duration)."""
        with self._lock:
            return fn(self)

    # ------------------------------------------------------------------ #
    # clients + logical clocks                                           #
    # ------------------------------------------------------------------ #
    def attach_columns(self, cols: FleetColumns) -> None:
        """Bind this store to a shared `FleetColumns` arena: existing and
        future `ClientRecord`s keep their clocks/online flags in the
        arena's numpy columns (fleet-wide gauges become one reduction)."""
        with self._lock:
            self._columns = cols
            for rec in self._clients.values():
                rec.bind(cols)

    @property
    def columns(self) -> FleetColumns | None:
        return self._columns

    def register_client(
        self, client_id: str, metadata: dict[str, Any] | None = None
    ) -> ClientRecord:
        with self._lock:
            rec = self._clients.get(client_id)
            if rec is None:
                rec = ClientRecord(client_id=client_id, metadata=metadata or {})
                if self._columns is not None:
                    rec.bind(self._columns)
                self._clients[client_id] = rec
            elif metadata:
                rec.metadata.update(metadata)
            rec.online = True
            return rec

    def set_online(self, client_id: str, online: bool) -> None:
        with self._lock:
            self._require_client(client_id).online = online

    def doc_counts(self) -> dict[str, int]:
        """O(1) platform-inventory gauge: how many documents of each kind
        the store holds (``result_streams`` = tasks with >= 1 recorded
        result). The serve gateway's ``platform`` query reads this — dict
        `len` is constant-time, so the read never scans a collection."""
        with self._lock:
            return {
                "clients": len(self._clients),
                "payloads": len(self._payloads),
                "parameters": len(self._parameters),
                "assignments": len(self._assignments),
                "tasks": len(self._tasks),
                "result_streams": len(self._results),
            }

    def online_clients(self) -> list[str]:
        with self._lock:
            return sorted(c.client_id for c in self._clients.values() if c.online)

    def clients(self) -> list[ClientRecord]:
        with self._lock:
            return list(self._clients.values())

    def logical_clock(self, client_id: str) -> int:
        with self._lock:
            return self._require_client(client_id).logical_clock

    def _require_client(self, client_id: str) -> ClientRecord:
        rec = self._clients.get(client_id)
        if rec is None:
            raise NoSuchDocument(f"client {client_id}")
        return rec

    def _bump_clock(self, client_id: str) -> int:
        rec = self.register_client(client_id)
        rec.logical_clock += 1
        for w in list(self._watchers):
            w(client_id, rec.logical_clock)
        return rec.logical_clock

    def watch_clocks(self, fn: Callable[[str, int], None]) -> None:
        """Register a clock-change observer (the server uses this to push
        MQTT notifications)."""
        self._watchers.append(fn)

    # ------------------------------------------------------------------ #
    # document creation (user-initiated)                                 #
    # ------------------------------------------------------------------ #
    def put_payload(self, payload: Payload) -> Payload:
        with self._lock:
            if payload.payload_id in self._payloads:
                raise DocumentExists(payload.payload_id)
            self._payloads[payload.payload_id] = payload
            return payload

    def put_parameters(self, parameters: Parameters) -> Parameters:
        with self._lock:
            if parameters.parameters_id in self._parameters:
                raise DocumentExists(parameters.parameters_id)
            self._parameters[parameters.parameters_id] = parameters
            return parameters

    def put_assignment(
        self, assignment: Assignment, tasks: Iterable[Task]
    ) -> Assignment:
        """Atomically create an assignment with its tasks; bumps each target
        client's clock (task creation is a client-visible change)."""

        def txn(store: "StateStore") -> Assignment:
            tasks_list = list(tasks)
            if assignment.assignment_id in store._assignments:
                raise DocumentExists(assignment.assignment_id)
            for t in tasks_list:
                if t.task_id in store._tasks:
                    raise DocumentExists(t.task_id)
                if t.payload_id not in store._payloads:
                    raise NoSuchDocument(f"payload {t.payload_id}")
                if t.parameters_id and t.parameters_id not in store._parameters:
                    raise NoSuchDocument(f"parameters {t.parameters_id}")
            store._assignments[assignment.assignment_id] = assignment
            for t in tasks_list:
                store._tasks[t.task_id] = t
                store._results[t.task_id] = []
                store._active_by_client.setdefault(t.client_id, []).append(
                    t.task_id
                )
                store._bump_clock(t.client_id)
            return assignment

        return self.transaction(txn)

    # ------------------------------------------------------------------ #
    # task state (client- or user-initiated)                             #
    # ------------------------------------------------------------------ #
    def get_task(self, task_id: str) -> Task:
        with self._lock:
            t = self._tasks.get(task_id)
            if t is None:
                raise NoSuchDocument(f"task {task_id}")
            return t

    def get_payload(self, payload_id: str) -> Payload:
        with self._lock:
            p = self._payloads.get(payload_id)
            if p is None:
                raise NoSuchDocument(f"payload {payload_id}")
            return p

    def get_parameters(self, parameters_id: str) -> Parameters:
        with self._lock:
            p = self._parameters.get(parameters_id)
            if p is None:
                raise NoSuchDocument(f"parameters {parameters_id}")
            return p

    def get_assignment(self, assignment_id: str) -> Assignment:
        with self._lock:
            a = self._assignments.get(assignment_id)
            if a is None:
                raise NoSuchDocument(f"assignment {assignment_id}")
            return a

    def active_tasks_for(self, client_id: str) -> list[Task]:
        with self._lock:
            ids = self._active_by_client.get(client_id)
            if not ids:
                return []
            active = [
                t
                for i in ids
                if (t := self._tasks[i]).status == TaskStatus.ACTIVE
            ]
            if len(active) != len(ids):  # lazy prune of terminal tasks
                self._active_by_client[client_id] = [
                    t.task_id for t in active
                ]
            return sorted(active, key=lambda t: t.task_id)

    def submit_results(
        self,
        task_id: str,
        results: Iterable[Result],
        status: TaskStatus | None = None,
        error_log: str = "",
    ) -> int:
        """Client upload path. Atomic; idempotent on (task_id, seq).

        Per paper §4.1.1 the server only accepts results/status changes for
        ACTIVE tasks — anything else is *ignored* (returns 0), not an error:
        the client may legitimately race a user's cancel.
        Returns the number of newly recorded results.
        """

        def txn(store: "StateStore") -> int:
            task = store._tasks.get(task_id)
            if task is None:
                raise NoSuchDocument(f"task {task_id}")
            if task.status != TaskStatus.ACTIVE:
                return 0
            stored = store._results[task_id]
            accepted = 0
            for r in sorted(results, key=lambda r: r.seq):
                if r.task_id != task_id:
                    raise ValueError("result/task mismatch")
                if r.seq < len(stored):
                    continue  # duplicate retry — idempotent
                if r.seq != len(stored):
                    raise StaleWrite(
                        f"gap in result sequence for {task_id}: "
                        f"got {r.seq}, expected {len(stored)}"
                    )
                stored.append(r)
                accepted += 1
            new_task = task
            if accepted:
                new_task = replace(new_task, results_count=len(stored))
            if status is not None and status != TaskStatus.ACTIVE:
                new_task = new_task.with_status(status)
                if status == TaskStatus.ERROR and error_log:
                    new_task = replace(new_task, error_log=error_log)
            if new_task is not task:
                store._tasks[task_id] = new_task
                store._bump_clock(task.client_id)
            return accepted

        return self.transaction(txn)

    def cancel_task(self, task_id: str) -> bool:
        """User-initiated cancel. Only ACTIVE tasks can be canceled
        (paper §4.1.1); canceling a finished task is a no-op -> False."""

        def txn(store: "StateStore") -> bool:
            task = store._tasks.get(task_id)
            if task is None:
                raise NoSuchDocument(f"task {task_id}")
            if task.status != TaskStatus.ACTIVE:
                return False
            try:
                store._tasks[task_id] = task.with_status(TaskStatus.CANCELED)
            except InvalidTransition:
                return False
            store._bump_clock(task.client_id)
            return True

        return self.transaction(txn)

    def results_for(self, task_id: str, since_seq: int = 0) -> list[Result]:
        with self._lock:
            if task_id not in self._results:
                raise NoSuchDocument(f"task {task_id}")
            return list(self._results[task_id][since_seq:])

    # ------------------------------------------------------------------ #
    # client sync snapshot                                               #
    # ------------------------------------------------------------------ #
    def client_state(self, client_id: str) -> "ClientStateSnapshot":
        """What `fetchState` returns (paper §4.2.1): the client's current
        logical clock and all its ACTIVE tasks with result counts."""
        with self._lock:
            rec = self._require_client(client_id)
            tasks = self.active_tasks_for(client_id)
            return ClientStateSnapshot(
                client_id=client_id,
                ts=rec.logical_clock,
                tasks=tuple(
                    TaskSyncInfo(
                        task_id=t.task_id,
                        payload_id=t.payload_id,
                        parameters_id=t.parameters_id,
                        results_count=t.results_count,
                    )
                    for t in tasks
                ),
            )


@dataclass(frozen=True, slots=True)
class TaskSyncInfo:
    task_id: str
    payload_id: str
    parameters_id: str | None
    results_count: int


@dataclass(frozen=True, slots=True)
class ClientStateSnapshot:
    client_id: str
    ts: int
    tasks: tuple[TaskSyncInfo, ...]
