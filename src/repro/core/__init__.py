"""repro.core — the AutoSPADA platform: the paper's primary contribution.

State-based task orchestration for unreliable distributed workers:
centralized versioned state, logical-clock notifications, an Algorithm-1
sync loop on every client, container-semantics task execution, and a
plain-Python user programming model.
"""
from repro.core.broker import (
    Broker,
    FaultPlan,
    client_clock_topic,
    seeded_fault_plan,
)
from repro.core.client import EdgeClient, LocalDisk
from repro.core.documents import (
    Assignment,
    Parameters,
    Payload,
    Result,
    Task,
    TaskStatus,
)
from repro.core.faults import FlakyServer, NetworkError
from repro.core.payload_api import PayloadContext, TaskCanceled, dummy_context
from repro.core.sandbox import ContainerExit, ResourceLimits, run_inline
from repro.core.server import Server, make_platform
from repro.core.signals import (
    CsvSignalBroker,
    FleetSignalPlane,
    PlaneSignalView,
    RandomSignalBroker,
    ScriptedSignalBroker,
    SignalHandler,
)
from repro.core.statestore import StateStore
from repro.core.user import TaskCounts, User

__all__ = [
    "Assignment", "Broker", "ContainerExit", "CsvSignalBroker", "EdgeClient",
    "FaultPlan", "FlakyServer", "FleetSignalPlane", "LocalDisk",
    "NetworkError", "Parameters", "Payload", "PayloadContext",
    "PlaneSignalView", "RandomSignalBroker", "ResourceLimits", "Result",
    "ScriptedSignalBroker", "Server", "SignalHandler", "StateStore", "Task",
    "TaskCanceled", "TaskCounts", "TaskStatus", "User", "client_clock_topic",
    "dummy_context", "make_platform", "run_inline", "seeded_fault_plan",
]
