"""Container-semantics payload runner (paper §3.6, §4.2.2).

Docker gives the paper three things: (1) isolation of task code from the
host, (2) per-task resource limits, (3) a supervision contract — exit code
0 => FINISHED, non-zero => ERROR with logs uploaded, `docker stop` =>
forced exit on cancel. There is no Docker daemon in this environment, so
we reproduce the *contract*:

* payload source is executed in a restricted namespace (fresh module dict,
  curated builtins — no file/network access by default) — the isolation
  boundary is best-effort in-process, and documented as such in DESIGN.md;
* stdout/stderr are captured as the container log; an uncaught exception
  is a non-zero exit whose log is uploaded with the ERROR status;
* a cooperative cancel flag plays SIGTERM;
* resource accounting: wall/CPU time and published-result quotas, checked
  cooperatively (the paper's future-work §8.1.2 resource quotas).

Two run modes:
* ``run_inline``  — execute to completion on the caller's thread
  (deterministic simulation / property tests);
* ``ContainerThread`` — daemon-thread execution with an event queue
  (live examples, long-running payloads).
"""
from __future__ import annotations

import builtins
import contextlib
import dataclasses
import io
import threading
import time
import traceback
from typing import Any, Callable

from repro.core.payload_api import PayloadContext, TaskCanceled

# Builtins exposed to payload code. Deliberately excludes open/__import__-
# anything-goes; `import` of a whitelisted module set is allowed below.
_SAFE_BUILTIN_NAMES = [
    "abs", "all", "any", "bool", "bytes", "callable", "chr", "dict", "divmod",
    "enumerate", "filter", "float", "format", "frozenset", "getattr", "hasattr",
    "hash", "int", "isinstance", "issubclass", "iter", "len", "list", "map",
    "max", "min", "next", "object", "ord", "pow", "print", "range", "repr",
    "reversed", "round", "set", "setattr", "slice", "sorted", "str", "sum",
    "tuple", "type", "zip", "Exception", "ValueError", "TypeError", "KeyError",
    "IndexError", "RuntimeError", "StopIteration", "ZeroDivisionError", "True",
    "False", "None", "__build_class__", "__name__",
]

_ALLOWED_MODULES = {
    "math", "statistics", "json", "random", "collections", "itertools",
    "functools", "time", "base64", "struct", "numpy", "jax", "jax.numpy",
    "jax.random",
    "repro", "repro.fleet", "repro.fleet.federated", "repro.fleet.compression",
}


def _make_safe_import(ctx: "PayloadContext"):
    """`import autospada` inside a payload binds the task's context object
    (paper Listing 1); everything else resolves against a whitelist."""

    def _safe_import(name, globals=None, locals=None, fromlist=(), level=0):
        if name == "autospada":
            return ctx
        root = name.split(".")[0]
        if name in _ALLOWED_MODULES or root in {
            m.split(".")[0] for m in _ALLOWED_MODULES
        }:
            return builtins.__import__(name, globals, locals, fromlist, level)
        raise ImportError(
            f"module {name!r} is not available inside task containers"
        )

    return _safe_import


@dataclasses.dataclass
class ResourceLimits:
    """Cooperative quotas (paper §8.1.2 — 'amount of CPU and RAM that a
    task can allocate needs to be controllable')."""

    max_wall_seconds: float | None = None
    max_results: int | None = None


@dataclasses.dataclass
class ContainerExit:
    exit_code: int
    log: str
    canceled: bool = False

    @property
    def ok(self) -> bool:
        return self.exit_code == 0 and not self.canceled


class QuotaExceeded(Exception):
    pass


# Payload documents are immutable (paper §3.4.1) and one payload is shared
# by every task of an assignment, so at fleet scale the same source runs
# thousands of times per round. Cache the compiled code object per source.
_CODE_CACHE: dict[str, Any] = {}
_CODE_CACHE_MAX = 256


def _compiled(source: str):
    code = _CODE_CACHE.get(source)
    if code is None:
        if len(_CODE_CACHE) >= _CODE_CACHE_MAX:
            # evict the oldest entry (dict preserves insertion order) so
            # hot payloads survive a churn of one-off sources
            _CODE_CACHE.pop(next(iter(_CODE_CACHE)))
        code = compile(source, "<payload>", "exec")
        _CODE_CACHE[source] = code
    return code


def run_inline(
    source: str,
    ctx: PayloadContext,
    limits: ResourceLimits | None = None,
    extra_globals: dict[str, Any] | None = None,
) -> ContainerExit:
    """Execute payload `source` to completion under container semantics."""
    limits = limits or ResourceLimits()
    log = io.StringIO()
    start = time.monotonic()

    original_publish = ctx.publish

    def quota_publish(value: Any) -> None:
        if (
            limits.max_results is not None
            and ctx.published_count >= limits.max_results
        ):
            raise QuotaExceeded(f"max_results={limits.max_results}")
        if (
            limits.max_wall_seconds is not None
            and time.monotonic() - start > limits.max_wall_seconds
        ):
            raise QuotaExceeded(f"max_wall_seconds={limits.max_wall_seconds}")
        original_publish(value)

    ctx.publish = quota_publish  # type: ignore[method-assign]

    safe_builtins = {n: getattr(builtins, n) for n in _SAFE_BUILTIN_NAMES
                     if hasattr(builtins, n)}
    safe_builtins["True"], safe_builtins["False"], safe_builtins["None"] = (
        True, False, None,
    )
    safe_builtins["__import__"] = _make_safe_import(ctx)
    glb: dict[str, Any] = {
        "__builtins__": safe_builtins,
        "__name__": "__autospada_payload__",
        "autospada": ctx,
    }
    if extra_globals:
        glb.update(extra_globals)

    try:
        with contextlib.redirect_stdout(log), contextlib.redirect_stderr(log):
            exec(_compiled(source), glb)  # noqa: S102
        return ContainerExit(exit_code=0, log=log.getvalue())
    except TaskCanceled:
        return ContainerExit(exit_code=137, log=log.getvalue(), canceled=True)
    except BaseException:  # noqa: BLE001 — any crash is a container error
        log.write(traceback.format_exc())
        return ContainerExit(exit_code=1, log=log.getvalue())
    finally:
        ctx.publish = original_publish  # type: ignore[method-assign]


class ContainerThread:
    """Daemon-thread container with a supervisor callback — the in-process
    analogue of paper §4.2.2's per-task supervisor thread."""

    def __init__(
        self,
        source: str,
        ctx: PayloadContext,
        on_exit: Callable[[ContainerExit], None],
        limits: ResourceLimits | None = None,
    ):
        self._source = source
        self._ctx = ctx
        self._on_exit = on_exit
        self._limits = limits
        self._thread = threading.Thread(target=self._run, daemon=True)
        self.exit: ContainerExit | None = None

    def start(self) -> None:
        self._thread.start()

    def _run(self) -> None:
        self.exit = run_inline(self._source, self._ctx, self._limits)
        self._on_exit(self.exit)

    def stop(self) -> None:
        """`docker stop`: signal cancellation; the payload exits at its next
        API call."""
        self._ctx.cancel()

    def join(self, timeout: float | None = None) -> None:
        self._thread.join(timeout)

    @property
    def running(self) -> bool:
        return self._thread.is_alive()
