"""Device-sharded fleet signal plane.

`FleetSignalPlane` keeps the whole fleet's signals in one *host* array —
fine for thousands of vehicles, a single-host bottleneck at millions
(the ROADMAP's "sharded signal plane" item; OODIDA names central handling
of whole-fleet streams as the bottleneck AutoSPADA descends from).
`ShardedSignalPlane` lays the same structure of arrays out over a 1-D
``clients`` device mesh (`repro.sharding.fleet`):

* ``values``   `(capacity, n_signals)`      — rows split across devices;
* history ring `(history, capacity, n_signals)` — client axis split, the
  slot axis whole per device;
* offline mask `(capacity,)`                — aligned with the rows.

The per-tick step is jit'd ONCE with ``in_shardings``/``out_shardings``
pinning that layout, and fuses the drive-cycle evaluation with the ring
slot write (the ring buffer is donated, so the write is in place). Every
scenario op is elementwise per client row, so GSPMD partitions the step
with zero collectives: each device advances only its row shard. Because
the scenario step functions are pure and shared verbatim with the host
plane (`Scenario.step_fn`), the two planes are bit-for-bit identical —
the parity tests pin this down at N=1024 on 8 simulated devices.

Growth is shard-aware: capacity is always rounded up to a multiple of the
device count (`round_up_clients`), so a geometric double moves from one
evenly-divisible layout to another and never forces a resharding
collective on the tick path. Reads go through lazily synced host mirrors
(`values` / the ring are fetched device->host only when a payload
actually calls ``get_signal`` / ``get_signal_window``), which keeps the
hot tick loop free of blocking transfers; `PlaneSignalView`,
`SignalHandler`, NaN offline masking and the scenario generators all work
unchanged on top.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.signals import FleetSignalPlane
from repro.sharding import fleet as fleet_sharding


class ShardedSignalPlane(FleetSignalPlane):
    """`FleetSignalPlane` semantics over a client-sharded device layout.

    ``step_builder(capacity)`` must return the scenario's *pure* jax step
    (`t -> (capacity, n_signals)` float32) — `Scenario.step_fn` is the
    canonical source. Materialized traces stay host-only
    (`FleetSignalPlane.from_trace`); CSV playback works here too via
    `from_csv_fleet`, which streams one host row per tick into the
    sharded ring instead of materializing the trace.
    """

    def __init__(
        self,
        names: Sequence[str],
        n_clients: int,
        step_builder: Callable[[int], Callable[[jax.Array], jax.Array]],
        *,
        history: int = 256,
        growth: float = 2.0,
        mesh: Mesh | None = None,
    ):
        self.names = tuple(names)
        self._col = {n: j for j, n in enumerate(self.names)}
        self._growth = max(1.0, float(growth))
        self.mesh = mesh if mesh is not None else fleet_sharding.client_mesh()
        self._step_builder = step_builder
        #: host row source for CSV playback (`from_csv_fleet`); None for
        #: scenario planes, whose ticks are fully device-resident
        self._feed = None
        self._hist_cap = max(1, int(history))
        self.t = 0
        self.n_clients = int(n_clients)
        if self.n_clients < 0:
            raise ValueError("n_clients must be >= 0")
        # an empty fleet still allocates one device row per shard, so the
        # degenerate --clients 0 config works like the host plane's (0, S)
        self._capacity = fleet_sharding.round_up_clients(
            max(1, self.n_clients), self.mesh
        )
        self._compile(self._capacity)
        self._dvalues = self._values_fn(jnp.int32(0))
        if self._dvalues.shape != (self._capacity, len(self.names)):
            raise ValueError(
                f"step_builder must return (capacity, {len(self.names)}), "
                f"got {self._dvalues.shape}"
            )
        self._dhist = self._init_ring_fn(self._dvalues)
        self._offline = np.zeros(self._capacity, bool)
        self._doffline = jax.device_put(
            self._offline, fleet_sharding.mask_sharding(self.mesh)
        )
        self._mask_dirty = False
        self._hist_len = 1
        # lazily synced host mirrors — the read path is unchanged base code
        self._values = np.asarray(self._dvalues)
        self._hist = np.asarray(self._dhist)
        self._values_dirty = False
        self._hist_dirty = False
        self._sketch_cache: dict = {}
        #: device->host ring transfers so far — the sketch path must
        #: never bump it (asserted in the fleet/sketch_* benchmark)
        self.ring_syncs = 0

    @property
    def devices(self) -> int:
        return fleet_sharding.device_count(self.mesh)

    # -- compiled per-capacity machinery -------------------------------- #
    def _compile(self, cap: int) -> None:
        """Build and jit the per-tick advance for one capacity, with the
        client-sharded layout pinned on both sides. Called O(log N) times
        across N joins (geometric growth), like the host plane's series
        rebuilds."""
        step = self._step_builder(cap)
        rep = fleet_sharding.replicated(self.mesh)
        vsh = fleet_sharding.values_sharding(self.mesh)
        rsh = fleet_sharding.ring_sharding(self.mesh)
        msh = fleet_sharding.mask_sharding(self.mesh)
        hist_cap = self._hist_cap

        def tick(t, hist, offline):
            vals = step(t)
            row = jnp.where(offline[:, None], jnp.nan, vals)
            hist = jax.lax.dynamic_update_slice_in_dim(
                hist, row[None], t % hist_cap, axis=0
            )
            return vals, hist

        self._tick_fn = jax.jit(
            tick,
            in_shardings=(rep, rsh, msh),
            out_shardings=(vsh, rsh),
            donate_argnums=(1,),
        )
        self._values_fn = jax.jit(step, out_shardings=vsh)

        def feed_tick(t, vals, hist, offline):
            # host-fed variant of tick(): the row arrives device-placed
            # from the CSV stream instead of from the scenario step
            row = jnp.where(offline[:, None], jnp.nan, vals)
            hist = jax.lax.dynamic_update_slice_in_dim(
                hist, row[None], t % hist_cap, axis=0
            )
            return vals, hist

        self._feed_fn = jax.jit(
            feed_tick,
            in_shardings=(rep, vsh, rsh, msh),
            out_shardings=(vsh, rsh),
            donate_argnums=(2,),
        )

        def init_ring(vals):
            ring = jnp.full((hist_cap, cap, vals.shape[1]), jnp.nan, jnp.float32)
            return ring.at[0].set(vals)

        self._init_ring_fn = jax.jit(init_ring, out_shardings=rsh)

        def join(hist, vals, i, slot):
            # A joining row's ring history is NaN except the current
            # tick. Written as an elementwise masked select on broadcast
            # iotas rather than a dynamic_update_slice along the sharded
            # client axis: GSPMD partitions iota+where shard-locally
            # (each device rewrites only its own row shard of the
            # donated ring), where the slice update's halo analysis can
            # materialize more than the touched shard.
            cli = jax.lax.broadcasted_iota(jnp.int32, (1, hist.shape[1], 1), 1)
            slt = jax.lax.broadcasted_iota(jnp.int32, (hist_cap, 1, 1), 0)
            col = jnp.where(slt == slot, vals[None], jnp.nan)
            return jnp.where(cli == i, col, hist)

        self._join_fn = jax.jit(
            join,
            in_shardings=(rsh, vsh, rep, rep),
            out_shardings=rsh,
            donate_argnums=(0,),
        )

        def grow_ring(old, vals0):
            ring = jnp.full((hist_cap, cap, vals0.shape[1]), jnp.nan, jnp.float32)
            return jax.lax.dynamic_update_slice_in_dim(ring, old, 0, axis=1)

        # old ring arrives with the previous (smaller, also even) layout;
        # jit re-lays it out into the new capacity once per regrow
        self._grow_ring_fn = jax.jit(grow_ring, out_shardings=rsh)

    # -- host mirror sync ------------------------------------------------ #
    def _sync_values(self) -> None:
        if self._values_dirty:
            self._values = np.asarray(self._dvalues)
            self._values_dirty = False

    def _sync_hist(self) -> None:
        if self._hist_dirty:
            self._hist = np.asarray(self._dhist)
            self._hist_dirty = False
            self.ring_syncs += 1

    def _sync_mask(self) -> None:
        """Upload the offline mask at most once per tick: K ignition
        toggles between steps cost one transfer, not K."""
        if self._mask_dirty:
            self._doffline = jax.device_put(
                self._offline, fleet_sharding.mask_sharding(self.mesh)
            )
            self._mask_dirty = False

    @property
    def values(self) -> np.ndarray:
        self._sync_values()
        return self._values[: self.n_clients]

    # -- the hot path ----------------------------------------------------- #
    def step(self) -> None:
        """Advance every device's row shard: ONE sharded jit call fusing
        the scenario step with the in-place (donated) ring slot write. No
        host transfer happens here — mirrors sync lazily on read.

        CSV-fed planes (`from_csv_fleet`) pull the next streamed host
        row instead, pad it to capacity, and run the same donated ring
        write — one host->device transfer per tick, never a trace."""
        self.t += 1
        self._sync_mask()
        if self._feed is not None:
            row = self._feed.series(self.t)
            padded = np.full(
                (self._capacity, len(self.names)), np.nan, np.float32
            )
            padded[: row.shape[0]] = row
            drow = jax.device_put(
                padded, fleet_sharding.values_sharding(self.mesh)
            )
            self._dvalues, self._dhist = self._feed_fn(
                jnp.int32(self.t), drow, self._dhist, self._doffline
            )
        else:
            self._dvalues, self._dhist = self._tick_fn(
                jnp.int32(self.t), self._dhist, self._doffline
            )
        self._hist_len = min(self._hist_len + 1, self._hist_cap)
        self._values_dirty = True
        self._hist_dirty = True

    def block_until_ready(self) -> None:
        """Wait for in-flight device work (benchmark fairness hook)."""
        jax.block_until_ready((self._dvalues, self._dhist))

    # -- reads: base logic over lazily synced mirrors --------------------- #
    def read(self, row: int, name: str) -> float | None:
        self._sync_values()
        return super().read(row, name)

    def window(self, row: int, name: str, k: int) -> list[float]:
        self._sync_hist()
        return super().window(row, name, k)

    def compute_sketches(self, name: str, spec, *, backend: str | None = None):
        """Fold the *device-resident* ring shards into per-client
        sketches: one `kernels.sketch.sketch_ring` call partitioned over
        the client axis (jit sharding propagation on the XLA twin,
        shard_map on the Pallas kernel). Only the `(spec.dim, capacity)`
        sketch block crosses device->host — the ring itself never does,
        and the lazy host mirror stays cold (`_hist_dirty` untouched)."""
        from repro.kernels import sketch as _sk

        col = self._col.get(name)
        n = self.n_clients
        if col is None or n == 0:
            return _sk.empty_fleet_sketches(spec, n)
        out = _sk.sketch_ring(
            self._dhist, self.t, self._hist_len, col, spec,
            backend=backend, mesh=self.mesh,
        )
        return _sk.sketches_from_device(spec, np.asarray(out)[:, :n])

    def set_online(self, row: int, online: bool) -> None:
        super().set_online(row, online)
        self._mask_dirty = True  # uploaded once at the next step

    # -- fleet growth ------------------------------------------------------ #
    def _ensure_capacity(self, n: int) -> None:
        """Geometric growth, rounded up to a device-count multiple: the
        doubled layout is evenly divisible again, so the recompiled tick
        keeps whole rows per device and never reshards mid-stream."""
        if n <= self._capacity:
            return
        cap = max(n, int(math.ceil(self._capacity * self._growth)))
        cap = fleet_sharding.round_up_clients(cap, self.mesh)
        old_hist = self._dhist
        self._compile(cap)
        # row-stable generators: rows < n_clients come back unchanged
        self._dvalues = self._values_fn(jnp.int32(self.t))
        self._dhist = self._grow_ring_fn(old_hist, self._dvalues)
        offline = np.zeros(cap, bool)
        offline[: self._capacity] = self._offline
        self._offline = offline
        self._mask_dirty = True
        self._capacity = cap
        self._values_dirty = True
        self._hist_dirty = True

    def add_client(self) -> int:
        """A new vehicle joins: amortized O(1) jitted ring-column init
        within spare capacity; past capacity the arrays double (rounded to
        the device count). Returns the new row index."""
        if self._feed is not None:
            # match the host CSV plane: a fixed trace defines the fleet
            raise ValueError(
                "this plane has a fixed fleet size (CSV playback); "
                "construct it via a scenario to support add_client"
            )
        i = self.n_clients
        self._ensure_capacity(i + 1)
        self.n_clients = i + 1
        self._dhist = self._join_fn(
            self._dhist,
            self._dvalues,
            jnp.int32(i),
            jnp.int32(self.t % self._hist_cap),
        )
        self._offline[i] = False
        self._mask_dirty = True
        self._hist_dirty = True
        return i

    # -- unsupported host-plane construction paths ------------------------- #
    @classmethod
    def from_trace(cls, *args, **kwargs):
        raise NotImplementedError(
            "sharded planes are scenario-backed; materialized traces stay "
            "on the host plane (FleetSignalPlane.from_trace)"
        )

    @classmethod
    def from_csv_fleet(
        cls,
        csv_texts: Sequence[str],
        *,
        history: int = 256,
        mesh: Mesh | None = None,
    ) -> "ShardedSignalPlane":
        """CSV playback on the sharded layout, through the same
        constant-memory `CsvFleetStream` the host plane uses: each tick
        streams ONE `(n_vehicles, n_signals)` host row, pads it to the
        device-rounded capacity, and feeds the donated ring write — the
        full trace is never materialized on host or device. Reads are
        bit-for-bit with `FleetSignalPlane.from_csv_fleet` (the parity
        test in `tests/test_signal_plane.py` pins it)."""
        from repro.core.signals import CsvFleetStream

        stream = CsvFleetStream(csv_texts)
        n = len(csv_texts)
        names = stream.names
        row0 = np.array(stream.series(0), np.float32, copy=True)

        def step_builder(cap):
            first = np.full((cap, len(names)), np.nan, np.float32)
            first[:n] = row0
            const = jnp.asarray(first)

            def step(t):
                # only evaluated at construction (t=0): every later tick
                # is host-fed through `step()`'s feed branch
                return const

            return step

        plane = cls(names, n, step_builder, history=history, mesh=mesh)
        plane._feed = stream
        return plane
