"""The user library (paper §5.2): `User`, document builders, method
chaining, and streaming/await result retrieval.

Mirrors the paper's workflow::

    user    = User(server, broker)
    payload = user.payload(source)
    params  = user.parameter({"seconds": 5, "signal_name": name})
    tasks   = [user.task(c, payload, params) for c in user.online_clients()]
    assign  = user.assignment("Mean speed", tasks)
    results = assign.commit().await_results(pump)

Documents are *builders* until `commit()` — nothing touches the database
before that, matching "this payload object has not yet been committed".
`await_results`/`stream` consume the AMQP-style topics the server publishes
result/status updates on; `results()` is the on-demand retrieval path.

Because the whole platform is simulated in-process, blocking waits take a
`pump` callable that advances the world (delivers broker messages, steps
clients). Live deployments would simply block on the queue.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

from repro.core.broker import (
    Broker,
    assignment_results_topic,
    assignment_status_topic,
)
from repro.core.documents import TaskStatus

TERMINAL = {TaskStatus.FINISHED, TaskStatus.ERROR, TaskStatus.CANCELED}
_TERMINAL_VALUES = {s.value for s in TERMINAL}


@dataclass(frozen=True)
class TaskCounts:
    """O(1) snapshot of an assignment's task lifecycle — maintained by
    status *events* (the broker's status stream), never by re-scanning
    every task. `pump_until_deadline` closes rounds on these."""

    finished: int = 0
    error: int = 0
    canceled: int = 0
    active: int = 0

    @property
    def terminal(self) -> int:
        return self.finished + self.error + self.canceled


@dataclass
class PayloadDoc:
    user: "User"
    source: str
    name: str = ""
    payload_id: str | None = None

    def commit(self) -> "PayloadDoc":
        if self.payload_id is None:
            self.payload_id = self.user.server.create_payload(
                self.source, self.name
            ).payload_id
        return self


@dataclass
class ParametersDoc:
    user: "User"
    value: Any
    parameters_id: str | None = None

    def commit(self) -> "ParametersDoc":
        if self.parameters_id is None:
            self.parameters_id = self.user.server.create_parameters(
                self.value
            ).parameters_id
        return self


@dataclass
class TaskDoc:
    user: "User"
    client_id: str
    payload: PayloadDoc
    parameters: ParametersDoc | None = None
    task_id: str | None = None


@dataclass
class AssignmentDoc:
    user: "User"
    name: str
    tasks: list[TaskDoc]
    assignment_id: str | None = None
    _results_sub: Any = field(default=None, repr=False)
    _status_sub: Any = field(default=None, repr=False)
    #: task_id -> terminal status value, folded in from status events; the
    #: dict makes the fold idempotent under QoS-1 redeliveries
    _terminal: dict = field(default_factory=dict, repr=False)
    _n_finished: int = field(default=0, repr=False)
    _n_error: int = field(default=0, repr=False)
    _n_canceled: int = field(default=0, repr=False)
    _task_ids: set = field(default_factory=set, repr=False)

    # ------------------------------------------------------------------ #
    def commit(self) -> "AssignmentDoc":
        """Commit the assignment and every related uncommitted document
        (paper: 'including all related documents if they have not been
        committed yet'). Subscribes to result/status streams *before* the
        tasks become visible so no update can be missed."""
        if self.assignment_id is not None:
            return self
        for t in self.tasks:
            t.payload.commit()
            if t.parameters is not None:
                t.parameters.commit()
        specs = [
            (
                t.client_id,
                t.payload.payload_id,
                t.parameters.parameters_id if t.parameters else None,
            )
            for t in self.tasks
        ]
        # Pre-subscribe with a wildcard: the assignment id is not known
        # until creation, but subscribing before task visibility matters
        # more; we filter by assignment afterwards. The subscriptions are
        # `reliable` — the user's AMQP queue sits next to the server, so
        # the vehicle-link delay faults don't apply (duplicates still do:
        # the terminal fold below is idempotent per task).
        results_sub = self.user.broker.subscribe(
            "assignments/*/results", qos=1, reliable=True
        )
        status_sub = self.user.broker.subscribe(
            "assignments/*/status", qos=1, reliable=True
        )
        assignment = self.user.server.create_assignment(self.name, specs)
        self.assignment_id = assignment.assignment_id
        for t, task_id in zip(self.tasks, assignment.task_ids):
            t.task_id = task_id
        self._results_sub = results_sub
        self._status_sub = status_sub
        self._task_ids = {t.task_id for t in self.tasks}
        # every FINISHED/ERROR/CANCELED transition lands here the moment
        # the server publishes it — counts() never rebuilds statuses
        status_sub.wake = self._absorb_status_events
        self._absorb_status_events()
        return self

    # ------------------------------------------------------------------ #
    def _my_topic(self, kind: str) -> str:
        assert self.assignment_id is not None
        return (
            assignment_results_topic(self.assignment_id)
            if kind == "results"
            else assignment_status_topic(self.assignment_id)
        )

    def stream_results(self) -> Iterator[dict]:
        """Lazy iterator over result messages received so far."""
        topic = self._my_topic("results")
        for msg in self._results_sub.drain():
            if msg.topic == topic:
                yield msg.value

    def statuses(self) -> dict[str, str]:
        """Current task statuses via a bulk server re-scan — O(n_tasks)
        per call. Deprecated on hot paths (the parity oracles and tests
        keep using it); drivers close rounds on `counts()` instead."""
        out = {}
        for t in self.tasks:
            assert t.task_id is not None
            out[t.task_id] = self.user.server.task(t.task_id).status.value
        return out

    # -- event-maintained lifecycle counters --------------------------- #
    def _absorb_status_events(self) -> None:
        """Fold pending status messages into the per-task terminal dict.
        Runs from the subscription's `wake` hook, i.e. synchronously with
        the store transition (reliable sub), so the counters never lag the
        server truth. Idempotent: duplicates and foreign assignments'
        wildcard-matched messages are discarded."""
        sub = self._status_sub
        if sub is None:
            return
        topic = self._my_topic("status")
        for msg in sub.drain():
            if msg.topic != topic:
                continue
            v = msg.value
            tid, status = v["task_id"], v["status"]
            if tid not in self._task_ids or tid in self._terminal:
                continue
            if status not in _TERMINAL_VALUES:
                continue
            self._terminal[tid] = status
            if status == TaskStatus.FINISHED.value:
                self._n_finished += 1
            elif status == TaskStatus.ERROR.value:
                self._n_error += 1
            else:
                self._n_canceled += 1

    def counts(self) -> TaskCounts:
        """O(1) lifecycle counters (finished/error/canceled/active),
        maintained by status events — the hot-path replacement for
        `statuses()` scans in `pump_until_deadline`/`await_results`."""
        assert self.assignment_id is not None, "commit() first"
        done = self._n_finished + self._n_error + self._n_canceled
        return TaskCounts(
            finished=self._n_finished,
            error=self._n_error,
            canceled=self._n_canceled,
            active=len(self.tasks) - done,
        )

    def await_results(
        self,
        pump: Callable[[], None],
        max_pumps: int = 100_000,
    ) -> dict[str, list[Any]]:
        """Wait for all tasks to finish, then return all results
        (paper §5.2.1's `assign.commit().await_results()`).

        `pump()` advances the simulated world one step; a real deployment
        would block on the AMQP queue instead."""
        assert self.assignment_id is not None, "commit() first"
        for _ in range(max_pumps):
            if self.counts().active == 0:
                return self.results()
            pump()
        raise TimeoutError("assignment did not finish")

    def results(self) -> dict[str, list[Any]]:
        """On-demand retrieval of every recorded result per task."""
        out: dict[str, list[Any]] = {}
        for t in self.tasks:
            assert t.task_id is not None
            out[t.task_id] = [
                r.value for r in self.user.server.results(t.task_id)
            ]
        return out

    def cancel(self) -> int:
        n = 0
        for t in self.tasks:
            assert t.task_id is not None
            n += bool(self.user.server.cancel_task(t.task_id))
        return n


class User:
    """Entry point for everything a user does (paper §5.2: 'provides the
    User class through which all actions to the server are made')."""

    def __init__(self, server: Any, broker: Broker):
        self.server = server
        self.broker = broker

    def online_clients(self) -> list[str]:
        return self.server.online_clients()

    def payload(self, source: str, name: str = "") -> PayloadDoc:
        return PayloadDoc(self, source, name)

    def parameter(self, value: Any) -> ParametersDoc:
        return ParametersDoc(self, value)

    def task(
        self,
        client_id: str,
        payload: PayloadDoc,
        parameters: ParametersDoc | None = None,
    ) -> TaskDoc:
        return TaskDoc(self, client_id, payload, parameters)

    def assignment(self, name: str, tasks: list[TaskDoc]) -> AssignmentDoc:
        return AssignmentDoc(self, name, tasks)
