"""The user library (paper §5.2): `User`, document builders, method
chaining, and streaming/await result retrieval.

Mirrors the paper's workflow::

    user    = User(server, broker)
    payload = user.payload(source)
    params  = user.parameter({"seconds": 5, "signal_name": name})
    tasks   = [user.task(c, payload, params) for c in user.online_clients()]
    assign  = user.assignment("Mean speed", tasks)
    results = assign.commit().await_results(pump)

Documents are *builders* until `commit()` — nothing touches the database
before that, matching "this payload object has not yet been committed".
`await_results`/`stream` consume the AMQP-style topics the server publishes
result/status updates on; `results()` is the on-demand retrieval path.

Because the whole platform is simulated in-process, blocking waits take a
`pump` callable that advances the world (delivers broker messages, steps
clients). Live deployments would simply block on the queue.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

from repro.core.broker import (
    Broker,
    assignment_results_topic,
    assignment_status_topic,
)
from repro.core.documents import TaskStatus

TERMINAL = {TaskStatus.FINISHED, TaskStatus.ERROR, TaskStatus.CANCELED}


@dataclass
class PayloadDoc:
    user: "User"
    source: str
    name: str = ""
    payload_id: str | None = None

    def commit(self) -> "PayloadDoc":
        if self.payload_id is None:
            self.payload_id = self.user.server.create_payload(
                self.source, self.name
            ).payload_id
        return self


@dataclass
class ParametersDoc:
    user: "User"
    value: Any
    parameters_id: str | None = None

    def commit(self) -> "ParametersDoc":
        if self.parameters_id is None:
            self.parameters_id = self.user.server.create_parameters(
                self.value
            ).parameters_id
        return self


@dataclass
class TaskDoc:
    user: "User"
    client_id: str
    payload: PayloadDoc
    parameters: ParametersDoc | None = None
    task_id: str | None = None


@dataclass
class AssignmentDoc:
    user: "User"
    name: str
    tasks: list[TaskDoc]
    assignment_id: str | None = None
    _results_sub: Any = field(default=None, repr=False)
    _status_sub: Any = field(default=None, repr=False)

    # ------------------------------------------------------------------ #
    def commit(self) -> "AssignmentDoc":
        """Commit the assignment and every related uncommitted document
        (paper: 'including all related documents if they have not been
        committed yet'). Subscribes to result/status streams *before* the
        tasks become visible so no update can be missed."""
        if self.assignment_id is not None:
            return self
        for t in self.tasks:
            t.payload.commit()
            if t.parameters is not None:
                t.parameters.commit()
        specs = [
            (
                t.client_id,
                t.payload.payload_id,
                t.parameters.parameters_id if t.parameters else None,
            )
            for t in self.tasks
        ]
        # Pre-subscribe with a wildcard: the assignment id is not known
        # until creation, but subscribing before task visibility matters
        # more; we filter by assignment afterwards.
        results_sub = self.user.broker.subscribe("assignments/*/results", qos=1)
        status_sub = self.user.broker.subscribe("assignments/*/status", qos=1)
        assignment = self.user.server.create_assignment(self.name, specs)
        self.assignment_id = assignment.assignment_id
        for t, task_id in zip(self.tasks, assignment.task_ids):
            t.task_id = task_id
        self._results_sub = results_sub
        self._status_sub = status_sub
        return self

    # ------------------------------------------------------------------ #
    def _my_topic(self, kind: str) -> str:
        assert self.assignment_id is not None
        return (
            assignment_results_topic(self.assignment_id)
            if kind == "results"
            else assignment_status_topic(self.assignment_id)
        )

    def stream_results(self) -> Iterator[dict]:
        """Lazy iterator over result messages received so far."""
        topic = self._my_topic("results")
        for msg in self._results_sub.drain():
            if msg.topic == topic:
                yield msg.value

    def statuses(self) -> dict[str, str]:
        """Current task statuses, on demand (stateless server read)."""
        out = {}
        for t in self.tasks:
            assert t.task_id is not None
            out[t.task_id] = self.user.server.task(t.task_id).status.value
        return out

    def await_results(
        self,
        pump: Callable[[], None],
        max_pumps: int = 100_000,
    ) -> dict[str, list[Any]]:
        """Wait for all tasks to finish, then return all results
        (paper §5.2.1's `assign.commit().await_results()`).

        `pump()` advances the simulated world one step; a real deployment
        would block on the AMQP queue instead."""
        assert self.assignment_id is not None, "commit() first"
        for _ in range(max_pumps):
            statuses = self.statuses()
            if all(s != TaskStatus.ACTIVE.value for s in statuses.values()):
                return self.results()
            pump()
        raise TimeoutError("assignment did not finish")

    def results(self) -> dict[str, list[Any]]:
        """On-demand retrieval of every recorded result per task."""
        out: dict[str, list[Any]] = {}
        for t in self.tasks:
            assert t.task_id is not None
            out[t.task_id] = [
                r.value for r in self.user.server.results(t.task_id)
            ]
        return out

    def cancel(self) -> int:
        n = 0
        for t in self.tasks:
            assert t.task_id is not None
            n += bool(self.user.server.cancel_task(t.task_id))
        return n


class User:
    """Entry point for everything a user does (paper §5.2: 'provides the
    User class through which all actions to the server are made')."""

    def __init__(self, server: Any, broker: Broker):
        self.server = server
        self.broker = broker

    def online_clients(self) -> list[str]:
        return self.server.online_clients()

    def payload(self, source: str, name: str = "") -> PayloadDoc:
        return PayloadDoc(self, source, name)

    def parameter(self, value: Any) -> ParametersDoc:
        return ParametersDoc(self, value)

    def task(
        self,
        client_id: str,
        payload: PayloadDoc,
        parameters: ParametersDoc | None = None,
    ) -> TaskDoc:
        return TaskDoc(self, client_id, payload, parameters)

    def assignment(self, name: str, tasks: list[TaskDoc]) -> AssignmentDoc:
        return AssignmentDoc(self, name, tasks)
