"""Application-state data model (paper Fig. 3).

Users create *assignments* containing a set of *tasks*. Tasks reference
their assignment, a *payload* (the code to be executed), optional
*parameters*, and the ID of the client the task is intended for.

Task lifecycle (paper §4.1.1): tasks are ACTIVE upon creation and the only
valid transitions are ACTIVE -> {FINISHED, ERROR, CANCELED}. The server
ignores results submitted for non-active tasks.
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
from repro.core.counter import Counter
import json
from typing import Any, Mapping


class TaskStatus(str, enum.Enum):
    ACTIVE = "ACTIVE"
    FINISHED = "FINISHED"
    ERROR = "ERROR"
    CANCELED = "CANCELED"


#: The only transitions the state machine accepts (paper §4.1.1).
VALID_TRANSITIONS: Mapping[TaskStatus, frozenset[TaskStatus]] = {
    TaskStatus.ACTIVE: frozenset(
        {TaskStatus.FINISHED, TaskStatus.ERROR, TaskStatus.CANCELED}
    ),
    TaskStatus.FINISHED: frozenset(),
    TaskStatus.ERROR: frozenset(),
    TaskStatus.CANCELED: frozenset(),
}


def is_valid_transition(src: TaskStatus, dst: TaskStatus) -> bool:
    return dst in VALID_TRANSITIONS[src]


_ids = Counter()


def new_id(prefix: str) -> str:
    """Process-unique monotone document ids.

    Monotone (not random) ids matter twice at fleet scale: clients iterate
    pending uploads in sorted-id order, so random ids made the broker
    message interleaving — and with it any seeded fault schedule —
    irreproducible run to run; and uuid4's urandom call showed up in
    profiles of 1000-client simulations. Zero-padded hex keeps
    lexicographic order == creation order."""
    return f"{prefix}-{next(_ids):012x}"


def _json_canonical(value: Any) -> str:
    return json.dumps(value, sort_keys=True, separators=(",", ":"), default=str)


@dataclasses.dataclass(frozen=True, slots=True)
class Payload:
    """Immutable code document. Immutability (paper §3.4.1) is what makes
    client-side payload caching sound: the digest is the cache key."""

    payload_id: str
    source: str  # python source of the payload ("general Python scripts")
    name: str = ""

    @property
    def digest(self) -> str:
        return hashlib.sha256(self.source.encode()).hexdigest()

    @staticmethod
    def create(source: str, name: str = "") -> "Payload":
        return Payload(payload_id=new_id("pay"), source=source, name=name)


@dataclasses.dataclass(frozen=True, slots=True)
class Parameters:
    """Optional JSON-serializable value readable by the payload via the
    client library (paper §4.1) — e.g. distribute a model to many clients
    or point the same payload at different signal names per client."""

    parameters_id: str
    value_json: str

    @property
    def value(self) -> Any:
        return json.loads(self.value_json)

    @staticmethod
    def create(value: Any) -> "Parameters":
        return Parameters(
            parameters_id=new_id("par"), value_json=_json_canonical(value)
        )


@dataclasses.dataclass(frozen=True, slots=True)
class Task:
    """Client-specific unit of work. `results_count` mirrors the paper's
    sync-state summary ("each task has an ID and the number of results
    submitted")."""

    task_id: str
    assignment_id: str
    client_id: str
    payload_id: str
    parameters_id: str | None
    status: TaskStatus = TaskStatus.ACTIVE
    results_count: int = 0
    error_log: str = ""

    def with_status(self, status: TaskStatus) -> "Task":
        if not is_valid_transition(self.status, status):
            raise InvalidTransition(self.status, status)
        return dataclasses.replace(self, status=status)


class InvalidTransition(Exception):
    def __init__(self, src: TaskStatus, dst: TaskStatus):
        super().__init__(f"invalid task transition {src.value} -> {dst.value}")
        self.src, self.dst = src, dst


@dataclasses.dataclass(frozen=True, slots=True)
class Assignment:
    """Groups related tasks; every task needs an assignment (paper §5.2.1)."""

    assignment_id: str
    name: str
    task_ids: tuple[str, ...]


@dataclasses.dataclass(frozen=True, slots=True)
class Result:
    """A single published result for a task. `seq` is the per-task result
    sequence number (dense, starting at 0) — it is what makes result upload
    idempotent: re-submitting (task_id, seq) is a no-op."""

    task_id: str
    seq: int
    value_json: str

    @property
    def value(self) -> Any:
        return json.loads(self.value_json)

    @staticmethod
    def create(task_id: str, seq: int, value: Any) -> "Result":
        return Result(task_id=task_id, seq=seq, value_json=_json_canonical(value))
