"""Signal sources (paper §4.2.4).

The paper's Signal Handler subscribes to the WICE Signal Broker (CAN /
FlexRay buses) and keeps the *latest observed value* per signal in memory —
"the simplest way to determine the present value of stateful and infrequent
signals". We reproduce that normalization layer:

* `SignalBroker` — abstract pub/sub signal source;
* `RandomSignalBroker` — the paper's §5.1.1 "dummy library" behaviour
  (random values for any signal) used for local payload testing;
* `CsvSignalBroker` — the paper's §5.1.1 CSV playback ("control the values
  of signals by providing a CSV file with hard-coded signal values");
* `ScriptedSignalBroker` — deterministic programmable source for tests and
  single-vehicle scripting;
* `SignalHandler` — the client-side proxy + latest-value cache that tasks
  actually read from, insulating payloads from the concrete source.

Fleet scale changed the shape of this layer. Per-vehicle iterator brokers
cost O(n_clients × n_signals) Python per simulation tick — the dominant
cost at 1000+ vehicles — so the fleet's signals now live in one columnar
structure of arrays:

* `FleetSignalPlane` — the whole fleet's latest values as a single
  `(n_clients, n_signals)` float32 matrix plus a rolling-history ring,
  advanced by ONE call per simulator tick (typically a jit'd scenario
  step, see `repro.fleet.scenarios`);
* `PlaneSignalView` — a per-vehicle `SignalBroker` that is just a row
  index into the plane. `SignalHandler.get` reads through it, so payload
  code (`autospada.get_signal`) is unchanged.

`ScriptedSignalBroker`/`CsvSignalBroker` remain supported both standalone
(push semantics, exactly as before) and as *adapters* into the plane:
`FleetSignalPlane.from_trace` / `from_csv_fleet` load their columns and
play them back with identical latest-value semantics (blank cells hold the
previous value; exhausted columns hold their last value).
"""
from __future__ import annotations

import csv
import io
import itertools
import math
import threading
from collections import deque
from typing import Callable, Iterable, Iterator, Mapping, Sequence

import numpy as np


class SignalBroker:
    """Pub/sub source of (signal_name, value) observations."""

    def subscribe(self, names: Iterable[str], cb: Callable[[str, float], None]) -> None:
        raise NotImplementedError

    def tick(self) -> None:
        """Advance the source one step (simulation hook)."""
        raise NotImplementedError


class RandomSignalBroker(SignalBroker):
    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        self._subs: list[tuple[list[str], Callable[[str, float], None]]] = []

    def subscribe(self, names, cb):
        self._subs.append((list(names), cb))
        for n in list(names):  # immediately provide a value
            cb(n, float(self._rng.standard_normal()))

    def tick(self):
        for names, cb in self._subs:
            for n in names:
                cb(n, float(self._rng.standard_normal()))


class ScriptedSignalBroker(SignalBroker):
    """Signals driven by user-supplied iterators — deterministic tests.

    Subscription delivers the next scripted value immediately (MQTT
    retained-message semantics): a late subscriber still observes the
    signal's current value, matching the paper's latest-value cache intent.

    An iterator may yield ``None`` to mean "no observation this tick" —
    the subscriber's latest-value cache simply holds the previous value.
    This keeps multi-column sources (CSV playback) tick-aligned when one
    column has gaps.
    """

    def __init__(self, scripts: Mapping[str, Iterator[float]]):
        self._scripts = {k: iter(v) for k, v in scripts.items()}
        self._subs: list[tuple[list[str], Callable[[str, float], None]]] = []

    def _emit(self, name: str, cb: Callable[[str, float], None]) -> None:
        it = self._scripts.get(name)
        if it is None:
            return
        try:
            v = next(it)
        except StopIteration:
            return
        if v is not None:
            cb(name, float(v))

    def subscribe(self, names, cb):
        self._subs.append((list(names), cb))
        for n in list(names):
            self._emit(n, cb)

    def tick(self):
        for names, cb in self._subs:
            for n in names:
                self._emit(n, cb)


class CsvSignalBroker(ScriptedSignalBroker):
    """CSV playback: one column per signal, one row per tick.

    Robust to real-world CSVs: blank cells are skipped (the latest-value
    cache holds the previous observation for that tick), ragged rows and
    non-numeric cells raise errors naming the offending column and row.
    """

    def __init__(self, csv_text: str):
        columns = parse_signal_csv(csv_text)
        super().__init__({k: iter(v) for k, v in columns.items()})


def iter_signal_csv(csv_text: str) -> Iterator[list]:
    """Stream a signals CSV: yields the stripped header row first, then
    one ``list[float | None]`` per data tick (blank cells -> ``None``,
    header-aligned). This is the single source of CSV validation — a row
    with more or fewer cells than the header, or a non-numeric cell,
    raises ``ValueError`` naming the column and the 1-based data row
    (blank lines count toward row numbers but yield no tick).
    """
    reader = csv.reader(io.StringIO(csv_text))
    try:
        header = next(reader)
    except StopIteration:
        raise ValueError("signals CSV is empty (no header row)") from None
    header = [h.strip() for h in header]
    dupes = {n for n in header if header.count(n) > 1}
    if dupes:
        raise ValueError(
            f"signals CSV header repeats column(s): {', '.join(sorted(dupes))}"
        )
    yield header
    for rownum, row in enumerate(reader, start=1):
        if not row or (len(row) == 1 and not row[0].strip()):
            continue  # ignore trailing/blank lines entirely
        if len(row) != len(header):
            raise ValueError(
                f"signals CSV row {rownum} has {len(row)} cells, expected "
                f"{len(header)} (columns: {', '.join(header)})"
            )
        parsed: list[float | None] = []
        for name, cell in zip(header, row):
            cell = cell.strip()
            if not cell:
                parsed.append(None)  # blank: hold previous value
                continue
            try:
                parsed.append(float(cell))
            except ValueError:
                raise ValueError(
                    f"signals CSV column {name!r}, row {rownum}: "
                    f"cannot parse {cell!r} as a number"
                ) from None
        yield parsed


def parse_signal_csv(csv_text: str) -> dict[str, list[float | None]]:
    """Parse a signals CSV into tick-aligned columns (the materializing
    wrapper over `iter_signal_csv`; identical validation and errors)."""
    rows = iter_signal_csv(csv_text)
    header = next(rows)
    columns: dict[str, list[float | None]] = {name: [] for name in header}
    for parsed in rows:
        for name, v in zip(header, parsed):
            columns[name].append(v)
    return columns


class CsvFleetStream:
    """Constant-memory playback of one-CSV-per-vehicle signal traces.

    The materializing loader builds the whole `(n_ticks, n_vehicles,
    n_signals)` trace before the plane sees a single row — O(T·N·S)
    float32, the ingestion bottleneck of a 100k-vehicle campaign. This
    streams instead: pass 1 replays every CSV once through
    `iter_signal_csv` to validate all cells (the same errors the
    materializing path raises, still eager at construction) and collect
    the signal-name union; pass 2 replays rows one tick at a time into a
    single `(n_vehicles, n_signals)` latest-value matrix with per-cell
    forward fill. The working set is that one matrix — independent of
    trace length.

    `series` satisfies the plane's ``series_fn`` contract and is
    forward-only (monotonic ticks; asking for the current tick again
    returns the cached row). Exhausted vehicles hold their last row and
    signals a vehicle never reports stay NaN, so the resulting plane is
    bit-for-bit identical to `from_trace` over the materialized trace —
    `tests/test_signal_plane.py` pins the parity.
    """

    def __init__(self, csv_texts: Sequence[str]):
        self._texts = list(csv_texts)
        names: set[str] = set()
        for text in self._texts:  # pass 1: validate everything, eagerly
            rows = iter_signal_csv(text)
            names.update(next(rows))
            for _ in rows:
                pass
        self.names: tuple[str, ...] = tuple(sorted(names))
        col = {n: j for j, n in enumerate(self.names)}
        self._iters: list[Iterator[list]] = []
        self._cols: list[list[int]] = []  # header position -> plane column
        for text in self._texts:  # pass 2: playback iterators
            rows = iter_signal_csv(text)
            self._cols.append([col[n] for n in next(rows)])
            self._iters.append(rows)
        self._current = np.full(
            (len(self._texts), len(self.names)), np.nan, np.float32
        )
        self._t = -1

    def series(self, t: int) -> np.ndarray:
        if t == self._t:
            return self._current
        if t != self._t + 1:
            raise ValueError(
                f"CSV stream is forward-only: asked for tick {t} "
                f"at tick {self._t}"
            )
        self._t = t
        cur = self._current
        for i, rows in enumerate(self._iters):
            parsed = next(rows, None)
            if parsed is None:
                continue  # exhausted: hold the last row (latest-value)
            for j, v in zip(self._cols[i], parsed):
                if v is not None:
                    cur[i, j] = v
        return cur


# --------------------------------------------------------------------- #
# the columnar fleet signal plane                                        #
# --------------------------------------------------------------------- #
class FleetSignalPlane:
    """Structure-of-arrays latest-value store for an entire fleet.

    ``values`` is the `(n_clients, n_signals)` float32 matrix of every
    vehicle's current signal readings; ``step()`` advances the whole fleet
    with ONE call to ``series_fn(t)`` (a jit'd drive-cycle step from
    `repro.fleet.scenarios`, or a trace playback) instead of the old
    O(n_clients × n_signals) per-vehicle iterator loop. A rolling ring of
    the last ``history`` ticks backs windowed on-vehicle analytics
    (`autospada.get_signal_window`).

    Per-vehicle access goes through `view(row)` — a `PlaneSignalView`
    satisfying the `SignalBroker` interface, so `SignalHandler` and every
    payload keep working unchanged.

    NaN is the "no observation yet" marker: `read` maps it to ``None``
    (exactly what `SignalHandler.get` returns before a push broker's first
    callback).

    Growth is amortized: rows are overallocated geometrically (capacity
    doubling, controlled by ``growth``), so mass admission of N vehicles
    rebuilds the series (an XLA recompile for jit scenarios) and
    reallocates the history ring only O(log N) times, not N times. The
    generators are row-stable, so computing the spare capacity rows is
    harmless; ``values`` always exposes exactly the `n_clients` live rows.

    Offline semantics: plane *time* is fleet-global (every row's current
    value advances each `step`), but the history ring NaN-masks rows whose
    vehicle is powered off (`set_online`), so
    ``autospada.get_signal_window`` after re-ignition only contains values
    observed while the ignition was on — matching the scripted path, where
    a powered-off vehicle's iterators pause. The `values` matrix itself is
    untouched by masking.
    """

    def __init__(
        self,
        names: Sequence[str],
        series_fn: Callable[[int], np.ndarray],
        *,
        history: int = 256,
        grow_fn: Callable[[int], Callable[[int], np.ndarray]] | None = None,
        growth: float = 2.0,
    ):
        self.names: tuple[str, ...] = tuple(names)
        self._col = {n: j for j, n in enumerate(self.names)}
        self._series_fn = series_fn
        self._grow_fn = grow_fn
        self._growth = max(1.0, float(growth))
        self.t = 0
        self._values = np.array(series_fn(0), np.float32, copy=True)
        if self._values.ndim != 2 or self._values.shape[1] != len(self.names):
            raise ValueError(
                f"series_fn must return (n_clients, {len(self.names)}), "
                f"got {self._values.shape}"
            )
        self.n_clients = self._values.shape[0]
        self._capacity = self._values.shape[0]
        self._offline = np.zeros(self._capacity, bool)
        self._hist_cap = max(1, int(history))
        self._hist = np.full(
            (self._hist_cap, self._capacity, len(self.names)),
            np.nan,
            np.float32,
        )
        self._hist[0] = self._values
        self._hist_len = 1
        # one fleet-wide sketch per (tick, fleet size, signal, spec) —
        # see sketch_row
        self._sketch_cache: dict = {}

    @property
    def values(self) -> np.ndarray:
        """The live fleet's `(n_clients, n_signals)` latest values (a view
        into the capacity-sized backing array)."""
        return self._values[: self.n_clients]

    # -- construction adapters ----------------------------------------- #
    @classmethod
    def from_trace(
        cls,
        names: Sequence[str],
        trace: np.ndarray,
        *,
        history: int = 256,
    ) -> "FleetSignalPlane":
        """Play back a precomputed `(n_ticks, n_clients, n_signals)` trace.

        Ticks past the end hold the final row (latest-value semantics, the
        plane analogue of an exhausted scripted iterator)."""
        trace = np.asarray(trace, np.float32)
        if trace.ndim != 3 or trace.shape[2] != len(names):
            raise ValueError(f"trace must be (T, n, {len(names)}), got {trace.shape}")
        last = trace.shape[0] - 1

        def series(t: int) -> np.ndarray:
            return trace[min(t, last)]

        return cls(names, series, history=history)

    @classmethod
    def from_csv_fleet(
        cls,
        csv_texts: Sequence[str],
        *,
        history: int = 256,
        streamed: bool = True,
    ) -> "FleetSignalPlane":
        """Load one CSV per vehicle into a single plane (the
        `CsvSignalBroker` adapter path). Columns are tick-aligned; blank
        cells hold the previous value (leading blanks read as ``None``),
        short columns hold their last value.

        ``streamed`` (the default) replays rows through `CsvFleetStream`
        — one latest-value matrix of working memory regardless of trace
        length. ``streamed=False`` keeps the whole-trace materialization
        as the parity oracle; both produce bit-identical planes."""
        if streamed:
            stream = CsvFleetStream(csv_texts)
            return cls(stream.names, stream.series, history=history)
        per_vehicle = [parse_signal_csv(text) for text in csv_texts]
        names = sorted({n for cols in per_vehicle for n in cols})
        n_ticks = max(
            (len(v) for cols in per_vehicle for v in cols.values()), default=0
        )
        n_ticks = max(1, n_ticks)
        trace = np.full((n_ticks, len(csv_texts), len(names)), np.nan, np.float32)
        for i, cols in enumerate(per_vehicle):
            for j, name in enumerate(names):
                col = cols.get(name, [])
                last = math.nan
                for t in range(n_ticks):
                    v = col[t] if t < len(col) else None
                    if v is not None:
                        last = v
                    trace[t, i, j] = last
        return cls.from_trace(names, trace, history=history)

    # -- the hot path --------------------------------------------------- #
    def step(self) -> None:
        """Advance every vehicle's every signal: one series_fn call, one
        ring write. This is the whole fleet's per-tick signal cost.
        Offline rows are NaN-masked in the ring (not in `values`): a
        powered-off vehicle observes nothing while the ignition is off."""
        self.t += 1
        self._values = np.asarray(self._series_fn(self.t), np.float32)
        slot = self.t % self._hist_cap
        self._hist[slot] = self._values
        if self._offline.any():
            self._hist[slot, self._offline] = np.nan
        self._hist_len = min(self._hist_len + 1, self._hist_cap)

    def _check_row(self, row: int) -> int:
        """Spare capacity rows hold real scenario values (step computes the
        whole backing array), so an out-of-range row must fail fast rather
        than silently return a phantom vehicle's signals."""
        row = int(row)
        if not 0 <= row < self.n_clients:
            raise IndexError(
                f"row {row} out of range for a {self.n_clients}-vehicle plane"
            )
        return row

    def set_online(self, row: int, online: bool) -> None:
        """Ignition state for history-ring masking. While a row is offline
        its ring entries are NaN ("nothing observed"); the latest-value
        matrix keeps advancing because plane time is fleet-global."""
        self._offline[self._check_row(row)] = not online

    # -- per-vehicle reads ---------------------------------------------- #
    def read(self, row: int, name: str) -> float | None:
        row = self._check_row(row)
        j = self._col.get(name)
        if j is None:
            return None
        v = float(self._values[row, j])
        return None if math.isnan(v) else v

    def window(self, row: int, name: str, k: int) -> list[float]:
        """Last `k` observed values for one vehicle's signal, oldest
        first (at most `history`; NaN "not yet observed" entries are
        skipped, mirroring a push subscriber that saw no callback)."""
        row = self._check_row(row)
        j = self._col.get(name)
        if j is None:
            return []
        k = max(0, min(int(k), self._hist_len))
        start = self.t - k + 1
        idx = [(start + i) % self._hist_cap for i in range(k)]
        vals = self._hist[idx, row, j]
        return [float(v) for v in vals if not math.isnan(v)]

    # -- fused windowed sketches ---------------------------------------- #
    def compute_sketches(self, name: str, spec, *, backend: str | None = None):
        """Fold every vehicle's last-`spec.window` observations of one
        signal into compact sketches (Welford moments, fixed-bin
        histogram, quantile summary) in a single fused device call —
        `kernels.sketch.sketch_ring` over the whole history ring at
        once, instead of n_clients `window()` reads + Python folds.

        Each row is bit-identical to `sketch_reference` over that
        vehicle's `window()` (offline-NaN and short-history truncation
        included). The sharded plane overrides this to fold the
        device-resident ring so the host mirror stays cold."""
        import jax.numpy as jnp  # lazy: the host plane is jax-free until asked

        from repro.kernels import sketch as _sk

        col = self._col.get(name)
        n = self.n_clients
        if col is None or n == 0:
            return _sk.empty_fleet_sketches(spec, n)
        out = _sk.sketch_ring(
            jnp.asarray(self._hist), self.t, self._hist_len, col, spec,
            backend=backend,
        )
        return _sk.sketches_from_device(spec, np.asarray(out)[:, :n])

    def fleet_sketch(self, name: str, spec):
        """The fleet-wide sketch fold, served from the per-tick cache:
        the first caller at a given (tick, fleet size) triggers one
        `compute_sketches` call; every other caller that tick — another
        vehicle's payload, an analyst's gateway query — gets the cached
        `FleetSketches` back without touching the ring. The key carries
        `t` and `n_clients` so `step()`/`add_client` invalidate for free
        (`set_online` only affects *future* ring writes, so it doesn't
        need to)."""
        key = (self.t, self.n_clients, name, spec)
        sk = self._sketch_cache.get(key)
        if sk is None:
            self._sketch_cache.clear()
            sk = self.compute_sketches(name, spec)
            self._sketch_cache[key] = sk
        return sk

    def sketch_row(self, row: int, name: str, spec) -> dict:
        """One vehicle's windowed sketch out of the cached fleet-wide
        fold (`fleet_sketch`) — an O(1) dict build on every cache hit."""
        row = self._check_row(row)
        return self.fleet_sketch(name, spec).row(row)

    def view(self, row: int) -> "PlaneSignalView":
        return PlaneSignalView(self, self._check_row(row))

    # -- fleet growth ---------------------------------------------------- #
    def _ensure_capacity(self, n: int) -> None:
        """Grow the backing arrays to hold >= n rows, geometrically: the
        series rebuild (and its XLA recompile, for jit scenarios) and the
        history-ring reallocation happen O(log n) times across n joins."""
        if n <= self._capacity:
            return
        if self._grow_fn is None:
            raise ValueError(
                "this plane has a fixed fleet size (no grow_fn); "
                "construct it via a scenario to support add_client"
            )
        cap = max(n, int(math.ceil(self._capacity * self._growth)))
        self._series_fn = self._grow_fn(cap)
        # row-stable generators: rows < n_clients come back unchanged
        self._values = np.array(self._series_fn(self.t), np.float32, copy=True)
        hist = np.full(
            (self._hist_cap, cap, len(self.names)), np.nan, np.float32
        )
        hist[:, : self._capacity, :] = self._hist
        self._hist = hist
        offline = np.zeros(cap, bool)
        offline[: self._capacity] = self._offline
        self._offline = offline
        self._capacity = cap

    def add_client(self) -> int:
        """A new vehicle joins. Amortized O(1): within spare capacity only
        the new row's ring history is initialized (NaN except the current
        tick — a join must not expose values 'observed' before it existed);
        past capacity the arrays double (`_ensure_capacity` raises for
        fixed-size planes). Returns the new row index."""
        i = self.n_clients
        self._ensure_capacity(i + 1)
        self.n_clients = i + 1
        self._hist[:, i, :] = np.nan
        self._hist[self.t % self._hist_cap, i, :] = self._values[i]
        self._offline[i] = False
        return i

    def add_clients(self, k: int) -> list[int]:
        """Mass admission: reserve capacity once, then O(1) per join."""
        if k <= 0:
            return []
        self._ensure_capacity(self.n_clients + k)
        return [self.add_client() for _ in range(k)]


class PlaneSignalView(SignalBroker):
    """One vehicle's `SignalBroker`-shaped window into the plane.

    Reads are pull-based (`read`/`read_window` — `SignalHandler` prefers
    these when present), so the per-vehicle cost of a fleet tick is zero:
    the plane's single `step()` already advanced this row. `subscribe` and
    `tick` keep push compatibility for standalone use.
    """

    def __init__(self, plane: FleetSignalPlane, row: int):
        self.plane = plane
        self.row = row
        self._subs: list[tuple[list[str], Callable[[str, float], None]]] = []

    def subscribe(self, names, cb):
        self._subs.append((list(names), cb))
        for n in list(names):
            v = self.plane.read(self.row, n)
            if v is not None:
                cb(n, v)

    def tick(self):
        # Standalone push compatibility only — the fleet path never calls
        # this (the plane steps once for all vehicles).
        for names, cb in self._subs:
            for n in names:
                v = self.plane.read(self.row, n)
                if v is not None:
                    cb(n, v)

    # pull interface (preferred by SignalHandler)
    def read(self, name: str) -> float | None:
        return self.plane.read(self.row, name)

    def read_window(self, name: str, k: int) -> list[float]:
        return self.plane.window(self.row, name, k)

    def read_sketch(self, name: str, spec) -> dict:
        return self.plane.sketch_row(self.row, name, spec)


class SignalHandler:
    """Client component: subscribes to the broker, caches the latest value
    of every signal a task has asked about (paper Fig. 4).

    Pull-capable brokers (`PlaneSignalView`) are read through directly —
    the cache is the plane column itself. Push brokers keep the classic
    callback-fed latest-value cache; a bounded per-signal history (so
    `window()` and `autospada.get_signal_window` work on any source) is
    recorded lazily, from the first `window()` call on, to keep the
    latest-value-only hot path free of per-observation deque work.
    """

    #: history retained per signal for push-based brokers
    HISTORY = 256

    def __init__(self, broker: SignalBroker):
        self._broker = broker
        self._pull = callable(getattr(broker, "read", None))
        self._latest: dict[str, float] = {}
        self._hist: dict[str, deque] = {}
        self._lock = threading.Lock()
        self._known: set[str] = set()

    def _observe(self, name: str, value: float) -> None:
        with self._lock:
            self._latest[name] = value
            h = self._hist.get(name)
            if h is not None:
                h.append(value)

    def ensure_subscribed(self, name: str) -> None:
        with self._lock:
            if name in self._known:
                return
            self._known.add(name)
        self._broker.subscribe([name], self._observe)

    def get(self, name: str) -> float | None:
        self.ensure_subscribed(name)
        if self._pull:
            return self._broker.read(name)
        with self._lock:
            return self._latest.get(name)

    def window(self, name: str, k: int) -> list[float]:
        """Last `k` observed values, oldest first. Push brokers start
        recording on the first `window()` call (seeded with the current
        latest value); pull brokers serve the plane's history ring."""
        self.ensure_subscribed(name)
        if self._pull and callable(getattr(self._broker, "read_window", None)):
            return self._broker.read_window(name, k)
        return self._push_window(name, k)

    def sketch(self, name: str, spec) -> dict | None:
        """Windowed sketch for one vehicle, served by the plane's cached
        fleet-wide device fold when the broker supports it. Returns
        ``None`` for push sources — the payload API then folds
        `window()` through the identical reference formula, so the
        answer is bit-for-bit the same either way."""
        self.ensure_subscribed(name)
        if self._pull and callable(getattr(self._broker, "read_sketch", None)):
            return self._broker.read_sketch(name, spec)
        return None

    def _push_window(self, name: str, k: int) -> list[float]:
        with self._lock:
            h = self._hist.get(name)
            if h is None:
                h = deque(maxlen=self.HISTORY)
                if name in self._latest:
                    h.append(self._latest[name])
                self._hist[name] = h
            if not h:
                return []
            k = max(0, int(k))
            return list(h)[-k:] if k else []


def constant(v: float) -> Iterator[float]:
    return itertools.repeat(float(v))
