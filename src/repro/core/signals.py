"""Signal sources (paper §4.2.4).

The paper's Signal Handler subscribes to the WICE Signal Broker (CAN /
FlexRay buses) and keeps the *latest observed value* per signal in memory —
"the simplest way to determine the present value of stateful and infrequent
signals". We reproduce that normalization layer:

* `SignalBroker` — abstract pub/sub signal source;
* `RandomSignalBroker` — the paper's §5.1.1 "dummy library" behaviour
  (random values for any signal) used for local payload testing;
* `CsvSignalBroker` — the paper's §5.1.1 CSV playback ("control the values
  of signals by providing a CSV file with hard-coded signal values");
* `ScriptedSignalBroker` — deterministic programmable source for tests and
  the vehicle-fleet simulation;
* `SignalHandler` — the client-side proxy + latest-value cache that tasks
  actually read from, insulating payloads from the concrete source.
"""
from __future__ import annotations

import csv
import io
import itertools
import threading
from typing import Callable, Iterable, Iterator, Mapping

import numpy as np


class SignalBroker:
    """Pub/sub source of (signal_name, value) observations."""

    def subscribe(self, names: Iterable[str], cb: Callable[[str, float], None]) -> None:
        raise NotImplementedError

    def tick(self) -> None:
        """Advance the source one step (simulation hook)."""
        raise NotImplementedError


class RandomSignalBroker(SignalBroker):
    def __init__(self, seed: int = 0):
        self._rng = np.random.default_rng(seed)
        self._subs: list[tuple[list[str], Callable[[str, float], None]]] = []

    def subscribe(self, names, cb):
        self._subs.append((list(names), cb))
        for n in list(names):  # immediately provide a value
            cb(n, float(self._rng.standard_normal()))

    def tick(self):
        for names, cb in self._subs:
            for n in names:
                cb(n, float(self._rng.standard_normal()))


class ScriptedSignalBroker(SignalBroker):
    """Signals driven by user-supplied iterators — deterministic tests.

    Subscription delivers the next scripted value immediately (MQTT
    retained-message semantics): a late subscriber still observes the
    signal's current value, matching the paper's latest-value cache intent.
    """

    def __init__(self, scripts: Mapping[str, Iterator[float]]):
        self._scripts = {k: iter(v) for k, v in scripts.items()}
        self._subs: list[tuple[list[str], Callable[[str, float], None]]] = []

    def subscribe(self, names, cb):
        self._subs.append((list(names), cb))
        for n in list(names):
            it = self._scripts.get(n)
            if it is None:
                continue
            try:
                cb(n, float(next(it)))
            except StopIteration:
                pass

    def tick(self):
        for names, cb in self._subs:
            for n in names:
                it = self._scripts.get(n)
                if it is None:
                    continue
                try:
                    cb(n, float(next(it)))
                except StopIteration:
                    pass


class CsvSignalBroker(ScriptedSignalBroker):
    """CSV playback: one column per signal, one row per tick."""

    def __init__(self, csv_text: str):
        reader = csv.DictReader(io.StringIO(csv_text))
        columns: dict[str, list[float]] = {}
        for row in reader:
            for k, v in row.items():
                columns.setdefault(k, []).append(float(v))
        super().__init__({k: iter(v) for k, v in columns.items()})


class SignalHandler:
    """Client component: subscribes to the broker, caches the latest value
    of every signal a task has asked about (paper Fig. 4)."""

    def __init__(self, broker: SignalBroker):
        self._broker = broker
        self._latest: dict[str, float] = {}
        self._lock = threading.Lock()
        self._known: set[str] = set()

    def _observe(self, name: str, value: float) -> None:
        with self._lock:
            self._latest[name] = value

    def ensure_subscribed(self, name: str) -> None:
        with self._lock:
            if name in self._known:
                return
            self._known.add(name)
        self._broker.subscribe([name], self._observe)

    def get(self, name: str) -> float | None:
        self.ensure_subscribed(name)
        with self._lock:
            return self._latest.get(name)


def constant(v: float) -> Iterator[float]:
    return itertools.repeat(float(v))
