"""The AutoSPADA edge client: Algorithm 1 (the sync loop) made executable.

The client keeps its local task state synchronized with the centralized
server state in a *state-based* (not RPC-based) fashion:

* the broker delivers only a logical-clock value ("your state changed");
* `fetchState` pulls the authoritative snapshot;
* `submit` pushes locally-buffered results / terminal statuses, then pulls
  a fresh snapshot ("both fetchState and submit send a new state back");
* `syncContainers` starts/stops task containers to match the active set;
* `syncingState` ensures at most one state exchange is in flight and
  `dirtyState` guarantees results arriving *during* an exchange trigger a
  follow-up `submit` (paper §4.2.1).

Everything durable lives on `LocalDisk`, which survives client "restarts"
(reconstructing `EdgeClient` over the same disk): unacknowledged results,
per-task next sequence numbers, cached immutable payload/parameter
documents, and task intermediate state (`cache_state`/`load_state`).

Determinism: spawned operations go into an op queue; `step()` executes one.
A driver (tests, simulator, or `run_until_idle`) chooses the interleaving.
Container execution is inline (synchronous) by default so property tests
are single-threaded; `thread_containers=True` runs payloads on daemon
threads for long-running/interactive use.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable

from repro.core import sandbox
from repro.core.broker import Broker, Subscription, client_clock_topic
from repro.core.columns import FleetColumns
from repro.core.documents import Result, TaskStatus
from repro.core.faults import NetworkError
from repro.core.payload_api import PayloadContext
from repro.core.signals import SignalBroker, SignalHandler
from repro.core.statestore import ClientStateSnapshot


@dataclass(slots=True)
class LocalDisk:
    """Durable client-side storage (survives restarts)."""

    payload_cache: dict[str, Any] = field(default_factory=dict)
    parameters_cache: dict[str, Any] = field(default_factory=dict)
    #: task_id -> list[Result] not yet confirmed recorded in the database
    unacked: dict[str, list[Result]] = field(default_factory=dict)
    #: task_id -> next result sequence number to assign
    next_seq: dict[str, int] = field(default_factory=dict)
    #: task_id -> (TaskStatus, log) terminal status pending upload
    terminal: dict[str, tuple[TaskStatus, str]] = field(default_factory=dict)
    #: task intermediate state (cache_state/load_state), keyed by task_id
    task_state: dict[str, Any] = field(default_factory=dict)
    #: task_ids whose terminal status the server has acknowledged
    done: set[str] = field(default_factory=set)


@dataclass(slots=True)
class _LocalTask:
    """An entry of the sync loop's `localTasks` map."""

    task_id: str
    payload_id: str
    parameters_id: str | None
    running: bool = False
    container: Any = None  # ContainerThread | None (inline => None)


class EdgeClient:
    """Slotted (no per-instance `__dict__`): at 100k+ vehicles the sync
    loop's Python-object overhead is the memory bill, so the layout is
    fixed and the fleet-wide scalars (`ts`, registration, unacked count)
    can live in a shared `FleetColumns` arena via `bind_columns` — one
    numpy element per client instead of a dict slot per object."""

    __slots__ = (
        "client_id", "server", "broker", "disk", "signal_handler",
        "_thread_containers", "_limits", "_metadata",
        "tasks", "local_tasks", "syncing_state", "dirty_state",
        "_ops", "_container_events", "_sub", "_wake_cb", "rpc_failures",
        "_cols", "_row", "_ts_local", "_registered_local",
    )

    def __init__(
        self,
        client_id: str,
        server: Any,  # Server | FlakyServer
        broker: Broker,
        disk: LocalDisk | None = None,
        signal_broker: SignalBroker | None = None,
        *,
        thread_containers: bool = False,
        limits: sandbox.ResourceLimits | None = None,
        metadata: dict[str, Any] | None = None,
    ):
        self.client_id = client_id
        self.server = server
        self.broker = broker
        self.disk = disk if disk is not None else LocalDisk()
        self.signal_handler = (
            SignalHandler(signal_broker) if signal_broker is not None else None
        )
        self._thread_containers = thread_containers
        self._limits = limits
        self._metadata = metadata or {}

        # --- columnar arena binding (optional; see bind_columns) ------- #
        self._cols: FleetColumns | None = None
        self._row = -1
        self._ts_local = 0
        self._registered_local = True

        # --- Algorithm 1 state ---------------------------------------- #
        self.ts = 0
        self.tasks: tuple = ()  # TaskSyncInfo tuple from last snapshot
        self.local_tasks: dict[str, _LocalTask] = {}
        self.syncing_state = False
        self.dirty_state = False

        # --- plumbing --------------------------------------------------#
        self._ops: list[tuple] = []  # pending spawned operations (FIFO)
        # deque, not queue.Queue: GIL-atomic append/popleft without a lock
        # acquisition per poll — the fleet scheduler reads `has_work` on
        # every serviced client and the old Queue.empty() mutex dominated
        # idle-fleet ticks.
        self._container_events: deque[tuple] = deque()
        self._sub: Subscription | None = None
        #: scheduler wake hook — called whenever new work arrives (an op is
        #: spawned, a broker notification lands, a container emits)
        self._wake_cb: Callable[[], None] | None = None
        self.rpc_failures = 0

    # ------------------------------------------------------------------ #
    # columnar arena binding                                             #
    # ------------------------------------------------------------------ #
    def bind_columns(self, cols: FleetColumns, row: int | None = None) -> None:
        """Move this client's scalar sync state (logical timestamp,
        registration flag, unacked-result count) into the shared arena.
        The attribute API is unchanged; reads/writes hit numpy columns."""
        r = cols.row_for(self.client_id) if row is None else row
        cols.client_ts[r] = self.ts
        cols.registered[r] = self._registered
        cols.unacked[r] = sum(len(v) for v in self.disk.unacked.values())
        self._cols, self._row = cols, r

    @property
    def ts(self) -> int:
        if self._cols is not None:
            return int(self._cols.client_ts[self._row])
        return self._ts_local

    @ts.setter
    def ts(self, value: int) -> None:
        if self._cols is not None:
            self._cols.client_ts[self._row] = value
        else:
            self._ts_local = int(value)

    @property
    def _registered(self) -> bool:
        if self._cols is not None:
            return bool(self._cols.registered[self._row])
        return self._registered_local

    @_registered.setter
    def _registered(self, value: bool) -> None:
        if self._cols is not None:
            self._cols.registered[self._row] = value
        else:
            self._registered_local = bool(value)

    def _recount_unacked(self) -> None:
        if self._cols is not None:
            self._cols.unacked[self._row] = sum(
                len(v) for v in self.disk.unacked.values()
            )

    # ------------------------------------------------------------------ #
    # lifecycle                                                          #
    # ------------------------------------------------------------------ #
    def bootstrap(self) -> None:
        """Register, subscribe to the per-client clock topic, and start an
        initial sync (also resumes any unacked uploads after a restart).
        Registration failure is survivable — a vehicle may reboot in a
        tunnel; the first successful op re-registers."""
        self._registered = False
        try:
            self.server.register_client(self.client_id, self._metadata)
            self._registered = True
        except NetworkError:
            self.rpc_failures += 1
        self._sub = self.broker.subscribe(client_clock_topic(self.client_id), qos=0)
        if self._wake_cb is not None:
            self._sub.wake = self._wake_cb
        self.syncing_state = True
        if any(self.disk.unacked.values()) or self.disk.terminal:
            # restart with pending uploads: go straight to submit
            self._spawn(("submit",))
        else:
            self._spawn(("fetch_state",))

    def _ensure_registered(self) -> None:
        if not self._registered:
            self.server.register_client(self.client_id, self._metadata)
            self._registered = True

    def resync(self) -> None:
        """Force a state pull (the paper's clients dial in on reconnect;
        a dropped QoS-0 notification is recovered by the next dial-in)."""
        if not self.syncing_state:
            self.syncing_state = True
            self._spawn(("fetch_state",))

    def shutdown(self) -> None:
        """Simulated crash/power-off: containers die, volatile state is
        lost; `LocalDisk` survives. Reconstruct EdgeClient to 'reboot'."""
        for lt in self.local_tasks.values():
            if lt.container is not None:
                lt.container.stop()
        if self._sub is not None:
            self.broker.unsubscribe(self._sub)

    # ------------------------------------------------------------------ #
    # event pump                                                         #
    # ------------------------------------------------------------------ #
    def poll(self) -> int:
        """Drain broker + container events through Algorithm 1's cases.
        Returns the number of events handled."""
        n = 0
        if self._sub is not None:
            for msg in self._sub.drain():
                self._on_clock(int(msg.value))
                n += 1
        while self._container_events:
            ev = self._container_events.popleft()
            self._on_container_event(*ev)
            n += 1
        return n

    def step(self) -> bool:
        """Execute one pending spawned op. Returns False if none pending."""
        if not self._ops:
            return False
        op = self._ops.pop(0)
        kind = op[0]
        if kind == "fetch_state":
            self._op_fetch_state()
        elif kind == "submit":
            self._op_submit()
        elif kind == "sync_containers":
            self._op_sync_containers(op[1])
        else:  # pragma: no cover
            raise AssertionError(op)
        return True

    def advance(self, budget: int = 1) -> int:
        """Simulator-driven stepping: run at most `budget` poll+step cycles
        and stop early once idle. Unlike `run_until_idle` this bounds the
        work done per simulation tick, so a discrete-event driver can model
        slow clients (small budgets) and fast ones (large budgets) against
        the same wall of events. Returns the number of productive cycles."""
        done = 0
        for _ in range(max(0, budget)):
            progressed = self.poll() > 0
            progressed |= self.step()
            if not progressed:
                break
            done += 1
        return done

    def run_until_idle(self, max_steps: int = 10_000) -> int:
        """Poll + step until no events and no ops remain."""
        steps = 0
        for _ in range(max_steps):
            progressed = self.poll() > 0
            progressed |= self.step()
            if not progressed:
                return steps
            steps += 1
        raise RuntimeError("sync loop did not quiesce")

    @property
    def has_work(self) -> bool:
        """O(1), lock-free: pending ops, undrained broker notifications, or
        container events. This is what an event-driven scheduler checks
        after servicing a client (and *only* then — arrival is signalled
        through the wake hook, not by polling this per tick)."""
        return bool(
            self._ops
            or (self._sub is not None and self._sub.has_pending)
            or self._container_events
        )

    @property
    def idle(self) -> bool:
        return not self.has_work

    def set_wake(self, cb: Callable[[], None] | None) -> None:
        """Install (or clear) the scheduler wake hook: `cb` fires whenever
        work arrives — a spawned op, a broker delivery to the clock topic,
        or a container result/status event. Spurious wakes are allowed
        (the scheduler re-checks `has_work`); missed wakes are not."""
        self._wake_cb = cb
        if self._sub is not None:
            self._sub.wake = cb

    def _spawn(self, op: tuple) -> None:
        self._ops.append(op)
        cb = self._wake_cb
        if cb is not None:
            cb()

    def _emit_container_event(self, ev: tuple) -> None:
        """Container -> sync-loop event enqueue (possibly from a container
        thread); wakes the scheduler so the event gets serviced."""
        self._container_events.append(ev)
        cb = self._wake_cb
        if cb is not None:
            cb()

    # ------------------------------------------------------------------ #
    # Algorithm 1 cases                                                  #
    # ------------------------------------------------------------------ #
    def _on_clock(self, ts_r: int) -> None:
        """case: received logical clock tsR from MQTT."""
        if ts_r > self.ts:
            self.ts = ts_r
            if not self.syncing_state:
                self.syncing_state = True
                self._spawn(("fetch_state",))

    def _on_state(self, s: ClientStateSnapshot) -> None:
        """case: received new state s (from fetchState or submit)."""
        if s.ts >= self.ts:
            self.ts = s.ts
            self.tasks = s.tasks
            self._absorb_acks(s)
            if self.dirty_state:
                # results/statuses arrived while syncing: go again
                self.dirty_state = False
                self._spawn(("submit",))
            else:
                self.syncing_state = False
                self._spawn(("sync_containers", s))
        else:
            # Snapshot is stale w.r.t. a clock value we already saw over
            # MQTT — fetch again (paper Algorithm 1, trailing fetchState).
            self._spawn(("fetch_state",))

    def _on_container_event(
        self,
        task_id: str,
        result_value: Any = None,
        status: TaskStatus | None = None,
        log: str = "",
    ) -> None:
        """case: received result r or status s from container for task t."""
        if task_id in self.disk.done:
            return
        if result_value is not None:
            seq = self.disk.next_seq.get(task_id, 0)
            self.disk.next_seq[task_id] = seq + 1
            self.disk.unacked.setdefault(task_id, []).append(
                Result.create(task_id, seq, result_value)
            )
            if self._cols is not None:
                self._cols.unacked[self._row] += 1
        if status is not None:
            self.disk.terminal[task_id] = (status, log)
            lt = self.local_tasks.get(task_id)
            if lt is not None:
                lt.running = False
        if self.syncing_state:
            self.dirty_state = True
        else:
            self.syncing_state = True
            self._spawn(("submit",))

    # ------------------------------------------------------------------ #
    # spawned operations                                                 #
    # ------------------------------------------------------------------ #
    def _op_fetch_state(self) -> None:
        try:
            self._ensure_registered()
            s = self.server.fetch_state(self.client_id)
        except NetworkError:
            self.rpc_failures += 1
            self._spawn(("fetch_state",))  # retry until the link returns
            return
        self._on_state(s)

    def _op_submit(self) -> None:
        """Upload buffered results/statuses, then pull a fresh snapshot."""
        try:
            self._ensure_registered()
            for task_id in sorted(
                set(self.disk.unacked) | set(self.disk.terminal)
            ):
                if task_id in self.disk.done:
                    continue
                pending = list(self.disk.unacked.get(task_id, ()))
                status, log = self.disk.terminal.get(task_id, (None, ""))
                if not pending and status is None:
                    continue
                self.server.submit(task_id, pending, status, log)
            s = self.server.fetch_state(self.client_id)
        except NetworkError:
            self.rpc_failures += 1
            self._spawn(("submit",))  # results stay on disk; retry
            return
        self._on_state(s)

    def _absorb_acks(self, s: ClientStateSnapshot) -> None:
        """Prune locally-cached results the snapshot proves are recorded
        ("persists results locally until they are confirmed to be recorded
        in the database"), and resolve terminal-status acknowledgements."""
        active = {t.task_id: t for t in s.tasks}
        for task_id, info in active.items():
            if task_id in self.disk.unacked:
                self.disk.unacked[task_id] = [
                    r for r in self.disk.unacked[task_id] if r.seq >= info.results_count
                ]
                if not self.disk.unacked[task_id]:
                    del self.disk.unacked[task_id]
            # first sight of a task: seed the sequence counter
            if task_id not in self.disk.next_seq:
                self.disk.next_seq[task_id] = info.results_count
        # Tasks we reported terminal that are no longer active: the server
        # accepted the transition. Drop everything local.
        for task_id in list(self.disk.terminal):
            if task_id not in active:
                self.disk.terminal.pop(task_id, None)
                self.disk.unacked.pop(task_id, None)
                self.disk.next_seq.pop(task_id, None)
                self.disk.task_state.pop(task_id, None)  # removed on completion
                self.disk.done.add(task_id)
        # Tasks canceled/removed server-side while we were offline:
        for task_id in list(self.disk.unacked):
            if task_id not in active and task_id not in self.disk.terminal:
                self.disk.unacked.pop(task_id, None)
                self.disk.next_seq.pop(task_id, None)
                self.disk.done.add(task_id)
        self._recount_unacked()

    def _op_sync_containers(self, s: ClientStateSnapshot) -> None:
        """Start/stop containers to match the active task set."""
        active = {t.task_id: t for t in s.tasks}
        # stop containers for tasks no longer active (canceled or removed)
        for task_id, lt in list(self.local_tasks.items()):
            if task_id not in active:
                if lt.container is not None and lt.running:
                    lt.container.stop()
                del self.local_tasks[task_id]
        # start containers for new tasks
        for task_id, info in active.items():
            if task_id in self.local_tasks or task_id in self.disk.terminal:
                continue
            if task_id in self.disk.done:
                continue
            lt = _LocalTask(
                task_id=task_id,
                payload_id=info.payload_id,
                parameters_id=info.parameters_id,
                running=True,
            )
            self.local_tasks[task_id] = lt
            self._start_container(lt)

    # ------------------------------------------------------------------ #
    # containers                                                         #
    # ------------------------------------------------------------------ #
    def _fetch_payload_cached(self, payload_id: str):
        """Immutable documents are cached on disk (paper §3.4.1) — a cache
        hit avoids a server round-trip entirely."""
        if payload_id not in self.disk.payload_cache:
            self.disk.payload_cache[payload_id] = self.server.fetch_payload(
                payload_id
            )
        return self.disk.payload_cache[payload_id]

    def _fetch_parameters_cached(self, parameters_id: str | None):
        if parameters_id is None:
            return None
        if parameters_id not in self.disk.parameters_cache:
            self.disk.parameters_cache[parameters_id] = self.server.fetch_parameters(
                parameters_id
            )
        return self.disk.parameters_cache[parameters_id]

    def _make_context(self, task_id: str, parameters: Any) -> PayloadContext:
        def get_signal(name: str) -> float | None:
            if self.signal_handler is None:
                return None
            return self.signal_handler.get(name)

        def get_signal_window(name: str, k: int) -> list[float]:
            if self.signal_handler is None:
                return []
            return self.signal_handler.window(name, k)

        def get_signal_sketch(name, k, bins, lo, hi, quantile_k):
            if self.signal_handler is None:
                return None
            from repro.kernels.sketch import SketchSpec

            return self.signal_handler.sketch(
                name,
                SketchSpec(
                    window=max(1, k), bins=bins, lo=lo, hi=hi,
                    quantile_k=quantile_k,
                ),
            )

        def publish(value: Any) -> None:
            self._emit_container_event((task_id, value, None, ""))

        return PayloadContext(
            get_signal=get_signal,
            get_signal_window=get_signal_window,
            get_signal_sketch=get_signal_sketch,
            publish=publish,
            parameters=parameters,
            state_cache=self.disk.task_state,
            task_key=task_id,
        )

    def _start_container(self, lt: _LocalTask) -> None:
        try:
            payload = self._fetch_payload_cached(lt.payload_id)
            parameters = self._fetch_parameters_cached(lt.parameters_id)
        except NetworkError:
            self.rpc_failures += 1
            # Could not pull the payload — leave the task for the next
            # sync_containers pass (triggered by the retry fetch).
            del self.local_tasks[lt.task_id]
            if not self.syncing_state:
                self.syncing_state = True
                self._spawn(("fetch_state",))
            return
        ctx = self._make_context(lt.task_id, parameters.value if parameters else None)

        def on_exit(exit: sandbox.ContainerExit) -> None:
            if exit.canceled:
                # user-canceled: server already moved the task out of
                # ACTIVE; nothing to upload.
                return
            status = TaskStatus.FINISHED if exit.ok else TaskStatus.ERROR
            self._emit_container_event(
                (lt.task_id, None, status, exit.log if not exit.ok else "")
            )

        if self._thread_containers:
            lt.container = sandbox.ContainerThread(
                payload.source, ctx, on_exit, self._limits
            )
            lt.container.start()
        else:
            exit = sandbox.run_inline(payload.source, ctx, self._limits)
            lt.running = False
            on_exit(exit)
