"""A checkpointable monotone counter.

`itertools.count` is the natural id/sequence generator, but its position
is opaque: you cannot read where a stream is, and you cannot put it back
there after a restore. Every platform id stream (document ids, broker
message ids, subscription order, delayed-delivery order, event-engine
sequence numbers) must survive a checkpoint/restore round trip at the
*exact* same position — the seeded fault plan hashes message ids and the
engine heap ties break on sequence numbers, so a counter that restarts
from zero silently changes the whole event interleaving.

`Counter` is `next()`-compatible with `itertools.count` (the call sites
keep reading `next(self._ids)`) and exposes the position as a plain
``.n`` attribute for `FleetCheckpoint` to read and set.
"""
from __future__ import annotations


class Counter:
    """Drop-in for ``itertools.count(start)`` with a readable/settable
    position: ``next(c)`` returns ``c.n`` and advances it."""

    __slots__ = ("n",)

    def __init__(self, start: int = 0):
        self.n = int(start)

    def __next__(self) -> int:
        v = self.n
        self.n += 1
        return v

    def __iter__(self) -> "Counter":
        return self

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Counter(n={self.n})"
