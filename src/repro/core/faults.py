"""Network fault injection for the client<->server RPC path.

The paper's resiliency claims (§2.3, §3.3.1) are about intermittent client
availability and unreliable mobile links. `FlakyServer` wraps a stateless
`Server` and fails RPCs according to a deterministic schedule so tests can
drive the sync loop through arbitrary loss patterns.
"""
from __future__ import annotations

from typing import Callable

from repro.core.server import Server


class NetworkError(Exception):
    """A dropped / timed-out RPC."""


class FlakyServer:
    """Proxy for Server whose calls fail when `should_fail(method, calls)`
    says so. Failure happens *before* the server observes the request for
    fetch-type calls and — worst case for the protocol — *after* the server
    applied it for submit-type calls (the ack is lost, forcing the client
    to retry and exercising idempotency)."""

    #: methods whose ack may be lost after the side effect was applied
    _ACK_LOSS = {"submit"}

    def __init__(
        self,
        inner: Server,
        should_fail: Callable[[str, int], bool] = lambda m, n: False,
    ):
        self._inner = inner
        self._should_fail = should_fail
        self.calls = 0
        self.failed = 0

    def __getattr__(self, name: str):
        attr = getattr(self._inner, name)
        if not callable(attr):
            return attr

        def wrapper(*args, **kwargs):
            self.calls += 1
            fail = self._should_fail(name, self.calls)
            if fail and name not in self._ACK_LOSS:
                self.failed += 1
                raise NetworkError(f"{name} dropped (call {self.calls})")
            out = attr(*args, **kwargs)
            if fail:
                self.failed += 1
                raise NetworkError(f"{name} ack lost (call {self.calls})")
            return out

        return wrapper
