"""Stateless server tier (paper §3.2).

Every public method reads the state it needs from the store, mutates it
transactionally, and returns — no state is retained between requests, so
any number of `Server` instances over the same store behave identically
(horizontal scaling). The tests exercise this by round-robining requests
over several instances.

Responsibilities (paper §4): persist user-created documents, serve client
`fetchState`/`submit` (the gRPC surface), and emit
  * per-client MQTT clock notifications (via `StateStore.watch_clocks`),
  * per-assignment AMQP result/status streams for users.
"""
from __future__ import annotations

from typing import Any, Iterable, Sequence

from repro.core.broker import (
    Broker,
    assignment_results_topic,
    assignment_status_topic,
    client_clock_topic,
)
from repro.core.documents import (
    Assignment,
    Parameters,
    Payload,
    Result,
    Task,
    TaskStatus,
    new_id,
)
from repro.core.statestore import ClientStateSnapshot, StateStore


class Server:
    """One stateless server instance. Construct as many as you like over
    the same (store, broker) pair."""

    def __init__(self, store: StateStore, broker: Broker):
        self._store = store
        self._broker = broker

    # -------------------------------------------------------------- #
    # user-facing API (wrapped by repro.core.user)                    #
    # -------------------------------------------------------------- #
    def create_payload(self, source: str, name: str = "") -> Payload:
        return self._store.put_payload(Payload.create(source, name))

    def create_parameters(self, value: Any) -> Parameters:
        return self._store.put_parameters(Parameters.create(value))

    def create_assignment(
        self,
        name: str,
        specs: Sequence[tuple[str, str, str | None]],
    ) -> Assignment:
        """specs: (client_id, payload_id, parameters_id|None) per task."""
        assignment_id = new_id("asg")
        tasks = [
            Task(
                task_id=new_id("tsk"),
                assignment_id=assignment_id,
                client_id=client_id,
                payload_id=payload_id,
                parameters_id=parameters_id,
            )
            for client_id, payload_id, parameters_id in specs
        ]
        assignment = Assignment(
            assignment_id=assignment_id,
            name=name,
            task_ids=tuple(t.task_id for t in tasks),
        )
        return self._store.put_assignment(assignment, tasks)

    def cancel_task(self, task_id: str) -> bool:
        ok = self._store.cancel_task(task_id)
        if ok:
            # fan the terminal transition out on the status stream, exactly
            # like `submit` does for FINISHED/ERROR: event-driven consumers
            # (AssignmentDoc.counts) must see every lifecycle edge
            task = self._store.get_task(task_id)
            self._broker.publish(
                assignment_status_topic(task.assignment_id),
                {"task_id": task_id, "status": task.status.value},
                qos=1,
            )
        return ok

    def online_clients(self) -> list[str]:
        return self._store.online_clients()

    def task(self, task_id: str) -> Task:
        return self._store.get_task(task_id)

    def assignment(self, assignment_id: str) -> Assignment:
        return self._store.get_assignment(assignment_id)

    def results(self, task_id: str, since_seq: int = 0) -> list[Result]:
        return self._store.results_for(task_id, since_seq)

    # -------------------------------------------------------------- #
    # client-facing API (the client gRPC surface)                     #
    # -------------------------------------------------------------- #
    def register_client(
        self, client_id: str, metadata: dict[str, Any] | None = None
    ) -> int:
        rec = self._store.register_client(client_id, metadata)
        return rec.logical_clock

    def fetch_state(self, client_id: str) -> ClientStateSnapshot:
        return self._store.client_state(client_id)

    def fetch_payload(self, payload_id: str) -> Payload:
        return self._store.get_payload(payload_id)

    def fetch_parameters(self, parameters_id: str) -> Parameters:
        return self._store.get_parameters(parameters_id)

    def submit(
        self,
        task_id: str,
        results: Iterable[Result],
        status: TaskStatus | None = None,
        error_log: str = "",
    ) -> int:
        """Client upload. Also fans accepted results / terminal statuses out
        to the user-facing AMQP streams."""
        results = list(results)
        task_before = self._store.get_task(task_id)
        accepted = self._store.submit_results(task_id, results, status, error_log)
        task_after = self._store.get_task(task_id)
        if accepted:
            base = task_before.results_count
            topic = assignment_results_topic(task_after.assignment_id)
            for r in results:
                if r.seq >= base:
                    self._broker.publish(
                        topic,
                        {"task_id": task_id, "seq": r.seq, "value": r.value},
                        qos=1,
                    )
        if task_after.status != task_before.status:
            self._broker.publish(
                assignment_status_topic(task_after.assignment_id),
                {"task_id": task_id, "status": task_after.status.value},
                qos=1,
            )
        return accepted


def make_platform(
    broker: Broker | None = None,
    store: StateStore | None = None,
    n_servers: int = 1,
) -> tuple[StateStore, Broker, list[Server]]:
    """Wire up a platform: store + broker + N stateless server instances.

    Installs the clock watcher that publishes the minimal MQTT notification
    (just the revision number) on every client-visible state change —
    paper §4: "The state update notification is a running count of the
    state revision for the individual client."
    """
    store = store or StateStore()
    broker = broker or Broker()

    def notify(client_id: str, clock: int) -> None:
        broker.publish(client_clock_topic(client_id), clock, qos=0)

    store.watch_clocks(notify)
    servers = [Server(store, broker) for _ in range(max(1, n_servers))]
    return store, broker, servers
