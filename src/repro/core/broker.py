"""MQTT/AMQP-like topic broker (paper §3.4.1).

The paper uses RabbitMQ: an MQTT bridge toward clients (minimal
notifications — just the client's current logical-clock value) and AMQP
toward users (streaming results/status updates). We reproduce the delivery
semantics the platform depends on:

* topic-based pub/sub with per-subscriber FIFO queues;
* QoS 0 ("at most once") and QoS 1 ("at least once" — RabbitMQ's MQTT
  plugin caps at QoS 1, which the paper calls out) — QoS 1 redelivers
  until acked and may therefore duplicate;
* **fault injection** (drop / duplicate / delay) so the resiliency claims
  (§2.3, §3.3.1) are *testable*: the sync-loop property tests drive the
  platform through lossy-broker schedules.

Because the notification payload is only a monotone counter, dropped or
duplicated notifications are harmless by design — that is the paper's core
resiliency argument, and the property tests in tests/test_syncloop_prop.py
check it mechanically.
"""
from __future__ import annotations

import fnmatch
import itertools
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator


@dataclass(frozen=True)
class Message:
    topic: str
    value: Any
    msg_id: int
    qos: int = 0


@dataclass
class FaultPlan:
    """Deterministic fault schedule: callables decide per message."""

    drop: Callable[[Message], bool] = lambda m: False
    duplicate: Callable[[Message], bool] = lambda m: False


class Subscription:
    """A per-subscriber FIFO queue. `poll()` is non-blocking (the simulated
    clients run event loops, not threads); `drain()` yields all pending."""

    def __init__(self, pattern: str, qos: int):
        self.pattern = pattern
        self.qos = qos
        self._queue: deque[Message] = deque()
        self._lock = threading.Lock()

    def _offer(self, msg: Message) -> None:
        with self._lock:
            self._queue.append(msg)

    def poll(self) -> Message | None:
        with self._lock:
            return self._queue.popleft() if self._queue else None

    def drain(self) -> Iterator[Message]:
        while True:
            m = self.poll()
            if m is None:
                return
            yield m

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)


class Broker:
    def __init__(self, faults: FaultPlan | None = None):
        self._subs: list[Subscription] = []
        self._faults = faults or FaultPlan()
        self._ids = itertools.count()
        self._lock = threading.Lock()
        self.published = 0
        self.delivered = 0
        self.dropped = 0

    def subscribe(self, pattern: str, qos: int = 0) -> Subscription:
        sub = Subscription(pattern, qos)
        with self._lock:
            self._subs.append(sub)
        return sub

    def unsubscribe(self, sub: Subscription) -> None:
        with self._lock:
            if sub in self._subs:
                self._subs.remove(sub)

    def publish(self, topic: str, value: Any, qos: int = 0) -> Message:
        msg = Message(topic=topic, value=value, msg_id=next(self._ids), qos=qos)
        self.published += 1
        with self._lock:
            subs = [s for s in self._subs if fnmatch.fnmatch(topic, s.pattern)]
        for sub in subs:
            eff_qos = min(qos, sub.qos)
            if eff_qos == 0 and self._faults.drop(msg):
                self.dropped += 1
                continue
            sub._offer(msg)
            self.delivered += 1
            # QoS 1 = at-least-once: fault plan may force a redelivery.
            if eff_qos >= 1 and self._faults.duplicate(msg):
                sub._offer(msg)
                self.delivered += 1
        return msg


# Topic helpers -------------------------------------------------------- #
def client_clock_topic(client_id: str) -> str:
    """Per-client MQTT topic carrying only the state revision counter."""
    return f"clients/{client_id}/clock"


def assignment_results_topic(assignment_id: str) -> str:
    """AMQP-style topic users subscribe to for streaming results."""
    return f"assignments/{assignment_id}/results"


def assignment_status_topic(assignment_id: str) -> str:
    return f"assignments/{assignment_id}/status"
