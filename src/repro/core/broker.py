"""MQTT/AMQP-like topic broker (paper §3.4.1).

The paper uses RabbitMQ: an MQTT bridge toward clients (minimal
notifications — just the client's current logical-clock value) and AMQP
toward users (streaming results/status updates). We reproduce the delivery
semantics the platform depends on:

* topic-based pub/sub with per-subscriber FIFO queues;
* QoS 0 ("at most once") and QoS 1 ("at least once" — RabbitMQ's MQTT
  plugin caps at QoS 1, which the paper calls out) — QoS 1 redelivers
  until acked and may therefore duplicate;
* **fault injection** (drop / duplicate / delay) so the resiliency claims
  (§2.3, §3.3.1) are *testable*: the sync-loop property tests and the
  fleet simulator drive the platform through lossy-broker schedules.

Because the notification payload is only a monotone counter, dropped,
duplicated, or late notifications are harmless by design — that is the
paper's core resiliency argument; tests/test_syncloop_prop.py checks it
per-client and tests/test_simulator.py checks it at fleet scale.

Scale note: exact-topic subscriptions (every per-client clock topic) are
indexed in a dict so a publish fans out in O(matching subscribers), not
O(all subscribers) — with thousands of simulated vehicles the previous
fnmatch scan made every clock bump O(fleet).

Time: the broker carries a logical tick clock (`now`). Messages a
`FaultPlan.delay` holds back are queued on a heap and released by
`advance()`, which discrete-event drivers (the fleet simulator) call once
per tick. Delivery order is deterministic: (due tick, enqueue order).
"""
from __future__ import annotations

import fnmatch
import heapq
from repro.core.counter import Counter
import threading
from collections import deque
from dataclasses import dataclass
from typing import Any, Callable, Iterator


@dataclass(frozen=True)
class Message:
    topic: str
    value: Any
    msg_id: int
    qos: int = 0


@dataclass
class FaultPlan:
    """Deterministic fault schedule: callables decide per message.

    `delay` returns the number of broker ticks to hold a delivery back;
    0 means deliver immediately (the default, and the behaviour when the
    driver never calls `Broker.advance`).
    """

    drop: Callable[[Message], bool] = lambda m: False
    duplicate: Callable[[Message], bool] = lambda m: False
    delay: Callable[[Message], int] = lambda m: 0


# --------------------------------------------------------------------- #
# seeded fault plans (fleet simulator)                                   #
# --------------------------------------------------------------------- #
_MASK64 = (1 << 64) - 1


def _hash01(seed: int, msg_id: int, salt: int) -> float:
    """Stateless splitmix64-style hash -> [0, 1). Deterministic in
    (seed, msg_id, salt) and independent of call order, so a fault plan
    built from it gives the same schedule no matter how the simulation
    interleaves publishes."""
    x = (
        seed * 0x9E3779B97F4A7C15
        + msg_id * 0xBF58476D1CE4E5B9
        + salt * 0x94D049BB133111EB
        + 0x2545F4914F6CDD1D
    ) & _MASK64
    x ^= x >> 30
    x = (x * 0xBF58476D1CE4E5B9) & _MASK64
    x ^= x >> 27
    x = (x * 0x94D049BB133111EB) & _MASK64
    x ^= x >> 31
    return x / 2.0**64


def seeded_fault_plan(
    seed: int,
    *,
    p_drop: float = 0.0,
    p_duplicate: float = 0.0,
    max_delay: int = 0,
) -> FaultPlan:
    """A deterministic lossy-broker schedule keyed by message id.

    Same seed => same drops/duplicates/delays for the same message ids,
    which is what makes whole fleet simulations replayable.
    """

    def drop(m: Message) -> bool:
        return _hash01(seed, m.msg_id, 1) < p_drop

    def duplicate(m: Message) -> bool:
        return _hash01(seed, m.msg_id, 2) < p_duplicate

    def delay(m: Message) -> int:
        if max_delay <= 0:
            return 0
        return int(_hash01(seed, m.msg_id, 3) * (max_delay + 1))

    return FaultPlan(drop=drop, duplicate=duplicate, delay=delay)


class Subscription:
    """A per-subscriber FIFO queue. `poll()` is non-blocking (the simulated
    clients run event loops, not threads); `drain()` yields all pending.

    `wake` is the delivery hook event-driven schedulers rely on: when set,
    it is invoked (outside the queue lock) after every `_offer`, so a
    subscriber becomes runnable the moment a message lands instead of
    being polled every tick.

    `reliable` models the user-side AMQP leg (paper §3.4.1): the user's
    queue lives in the datacenter next to the server, so the vehicle-link
    fault schedule's *delay* does not apply — deliveries land the same
    tick they are published. Duplicates still occur (AMQP is at-least-once
    here too), so reliable consumers must stay idempotent. Event-driven
    round accounting (`AssignmentDoc.counts`) depends on this: a status
    transition is observed the instant the store commits it, which is what
    keeps the event counters bit-for-bit in step with the dense
    `statuses()` oracle."""

    def __init__(
        self, pattern: str, qos: int, order: int = 0, reliable: bool = False
    ):
        self.pattern = pattern
        self.qos = qos
        self.order = order  # broker-wide subscription sequence number
        self.reliable = reliable
        self.wake: Callable[[], None] | None = None
        self._queue: deque[Message] = deque()
        self._lock = threading.Lock()

    def _offer(self, msg: Message) -> None:
        with self._lock:
            self._queue.append(msg)
        cb = self.wake
        if cb is not None:
            cb()

    @property
    def has_pending(self) -> bool:
        """Lock-free pending check (GIL-atomic deque truthiness) — the O(1)
        read `EdgeClient.has_work` does per serviced client, not per tick."""
        return bool(self._queue)

    def poll(self) -> Message | None:
        with self._lock:
            return self._queue.popleft() if self._queue else None

    def drain(self) -> Iterator[Message]:
        while True:
            m = self.poll()
            if m is None:
                return
            yield m

    def __len__(self) -> int:
        with self._lock:
            return len(self._queue)


def _is_exact(pattern: str) -> bool:
    return not any(ch in pattern for ch in "*?[")


class Broker:
    def __init__(self, faults: FaultPlan | None = None):
        #: exact-topic subscriptions, indexed by topic string
        self._exact: dict[str, list[Subscription]] = {}
        #: wildcard subscriptions, matched by fnmatch on publish
        self._wild: list[Subscription] = []
        self._faults = faults or FaultPlan()
        self._ids = Counter()
        self._sub_order = Counter()
        self._lock = threading.Lock()
        self.published = 0
        self.delivered = 0
        self.dropped = 0
        # -- logical time (discrete-event simulation hook) -------------- #
        self.now = 0
        self._delay_order = Counter()
        #: (due_tick, enqueue_order, subscription, message)
        self._delayed: list[tuple[int, int, Subscription, Message]] = []

    def subscribe(
        self, pattern: str, qos: int = 0, *, reliable: bool = False
    ) -> Subscription:
        sub = Subscription(
            pattern, qos, order=next(self._sub_order), reliable=reliable
        )
        with self._lock:
            if _is_exact(pattern):
                self._exact.setdefault(pattern, []).append(sub)
            else:
                self._wild.append(sub)
        return sub

    def unsubscribe(self, sub: Subscription) -> None:
        with self._lock:
            if _is_exact(sub.pattern):
                subs = self._exact.get(sub.pattern, [])
                if sub in subs:
                    subs.remove(sub)
                    if not subs:
                        del self._exact[sub.pattern]
            elif sub in self._wild:
                self._wild.remove(sub)
            # pending delayed deliveries to a dead subscriber are dropped
            self._delayed = [e for e in self._delayed if e[2] is not sub]
            heapq.heapify(self._delayed)

    def publish(self, topic: str, value: Any, qos: int = 0) -> Message:
        msg = Message(topic=topic, value=value, msg_id=next(self._ids), qos=qos)
        self.published += 1
        with self._lock:
            subs = list(self._exact.get(topic, ()))
            subs += [s for s in self._wild if fnmatch.fnmatch(topic, s.pattern)]
        # deterministic fan-out order = subscription order, exactly as the
        # previous single-list implementation delivered
        subs.sort(key=lambda s: s.order)
        for sub in subs:
            eff_qos = min(qos, sub.qos)
            if eff_qos == 0 and not sub.reliable and self._faults.drop(msg):
                self.dropped += 1
                continue
            self._deliver(sub, msg)
            # QoS 1 = at-least-once: fault plan may force a redelivery.
            if eff_qos >= 1 and self._faults.duplicate(msg):
                self._deliver(sub, msg)
        return msg

    def _deliver(self, sub: Subscription, msg: Message) -> None:
        ticks = 0 if sub.reliable else self._faults.delay(msg)
        if ticks > 0:
            with self._lock:
                heapq.heappush(
                    self._delayed,
                    (self.now + ticks, next(self._delay_order), sub, msg),
                )
            return
        sub._offer(msg)
        self.delivered += 1

    # ------------------------------------------------------------------ #
    # logical time                                                       #
    # ------------------------------------------------------------------ #
    def advance(self, ticks: int = 1) -> int:
        """Advance the broker clock, releasing due delayed messages in
        deterministic (due, enqueue-order) order. Returns #released."""
        with self._lock:
            self.now += ticks
            now = self.now
        released = 0
        while True:
            with self._lock:
                if not self._delayed or self._delayed[0][0] > now:
                    return released
                _, _, sub, msg = heapq.heappop(self._delayed)
            sub._offer(msg)
            self.delivered += 1
            released += 1

    @property
    def in_flight(self) -> int:
        """Delayed messages not yet released (simulator quiescence check)."""
        with self._lock:
            return len(self._delayed)


# Topic helpers -------------------------------------------------------- #
def client_clock_topic(client_id: str) -> str:
    """Per-client MQTT topic carrying only the state revision counter."""
    return f"clients/{client_id}/clock"


def assignment_results_topic(assignment_id: str) -> str:
    """AMQP-style topic users subscribe to for streaming results."""
    return f"assignments/{assignment_id}/results"


def assignment_status_topic(assignment_id: str) -> str:
    return f"assignments/{assignment_id}/status"
