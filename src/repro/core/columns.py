"""Columnar per-client control-plane state (`FleetColumns`).

At N=100k+ vehicles the object-per-vehicle layout dominates memory and
tick cost long before JAX does (ROADMAP item 3): every `EdgeClient`,
`ClientRecord`, and document dataclass carried a `__dict__`, and
fleet-wide scalars (logical clocks, sync sequence numbers, power flags,
straggler gating) lived scattered across those dicts. `FleetColumns` is
the structure-of-arrays arena those scalars move into — ONE numpy column
per field, indexed by a stable per-client row:

* ``clock``      int64 — statestore logical clocks (`ClientRecord`);
* ``online``     bool  — power / ignition state (`ClientRecord.online`);
* ``registered`` bool  — client bootstrap handshake (`EdgeClient`);
* ``client_ts``  int64 — client-side logical timestamps (`EdgeClient.ts`);
* ``unacked``    int32 — QoS-1 events awaiting broker acks (`LocalDisk`);
* ``runnable``   bool  — service gating (`FleetServiceScheduler`);
* ``straggler``  bool  — straggler designation (service).

`StateStore`, the service schedulers, and `FleetMetrics` all *view* these
columns instead of copying them, so a fleet-wide gauge (mean clock, count
online, total unacked) is one vectorized reduction. Rows are allocated by
`row_for(client_id)` and coincide with the pool's vehicle index for
`veh-NNN` ids; growth is geometric and preserves data, like the signal
plane's capacity doubling.

`deep_sizeof` is the memory auditor behind `FleetSimulator.memory_report`
— a recursive, memoized `sys.getsizeof` walk that understands numpy
buffers, containers, and slotted objects.
"""
from __future__ import annotations

import sys
from collections import deque
from typing import Any, Iterable

import numpy as np

#: column name -> dtype; the arena's whole schema. Checkpoint snapshots
#: save exactly these arrays (trimmed to n_rows) as content-addressed
#: blobs, so adding a column here automatically threads it through
#: `fleet/checkpoint.py`.
COLUMN_SPECS: dict[str, np.dtype] = {
    "clock": np.dtype(np.int64),
    "online": np.dtype(bool),
    "registered": np.dtype(bool),
    "client_ts": np.dtype(np.int64),
    "unacked": np.dtype(np.int32),
    "runnable": np.dtype(bool),
    "straggler": np.dtype(bool),
}

#: per-column fill for freshly allocated rows
_DEFAULTS: dict[str, Any] = {
    "clock": 0,
    "online": True,
    "registered": True,
    "client_ts": 0,
    "unacked": 0,
    "runnable": False,
    "straggler": False,
}


class FleetColumns:
    """The shared structure-of-arrays arena for per-client scalars.

    One instance per simulated fleet; every control-plane layer holds a
    reference and dereferences `cols.<name>` *at use time* (growth
    reallocates the arrays, so cached references go stale — viewers use
    properties, never stored arrays).
    """

    __slots__ = ("_cap", "n_rows", "_row", *COLUMN_SPECS)

    def __init__(self, capacity: int = 0):
        self._cap = max(1, int(capacity))
        self.n_rows = 0
        #: client_id -> row registry. `veh-NNN` ids land on row NNN by
        #: construction order, matching the pool / plane row index.
        self._row: dict[str, int] = {}
        for name, dtype in COLUMN_SPECS.items():
            setattr(self, name, np.full(self._cap, _DEFAULTS[name], dtype))

    # -- rows ----------------------------------------------------------- #
    def row_of(self, client_id: str) -> int | None:
        """The row for a known client, or None."""
        return self._row.get(client_id)

    def row_for(self, client_id: str) -> int:
        """The row for a client, allocating (and defaulting) a new one."""
        row = self._row.get(client_id)
        if row is None:
            row = self.n_rows
            self.ensure(row + 1)
            self.n_rows = row + 1
            self._row[client_id] = row
            for name in COLUMN_SPECS:
                getattr(self, name)[row] = _DEFAULTS[name]
        return row

    def ensure(self, n: int) -> None:
        """Grow capacity geometrically to hold at least n rows,
        preserving existing data (cheap amortized, like the plane)."""
        if n <= self._cap:
            return
        cap = max(int(n), 2 * self._cap)
        for name, dtype in COLUMN_SPECS.items():
            old = getattr(self, name)
            new = np.full(cap, _DEFAULTS[name], dtype)
            new[: self._cap] = old
            setattr(self, name, new)
        self._cap = cap

    @property
    def capacity(self) -> int:
        return self._cap

    def client_ids(self) -> Iterable[str]:
        return self._row.keys()

    # -- checkpoint surface --------------------------------------------- #
    def snapshot(self) -> dict[str, np.ndarray]:
        """Copies of every column trimmed to live rows (blob payload)."""
        n = self.n_rows
        return {name: getattr(self, name)[:n].copy() for name in COLUMN_SPECS}

    def load(self, arrays: dict[str, np.ndarray], ids: list[str]) -> None:
        """Overwrite the arena from a snapshot: row registry from `ids`
        (in row order), column data from `arrays`."""
        n = len(ids)
        self.ensure(n)
        self.n_rows = n
        self._row = {cid: i for i, cid in enumerate(ids)}
        for name, dtype in COLUMN_SPECS.items():
            col = getattr(self, name)
            col[:n] = np.asarray(arrays[name], dtype)
            col[n : self._cap] = _DEFAULTS[name]

    # -- memory accounting ---------------------------------------------- #
    def nbytes(self) -> int:
        return sum(getattr(self, name).nbytes for name in COLUMN_SPECS)


def deep_sizeof(obj: Any, _seen: set[int] | None = None) -> int:
    """Recursive, memoized memory footprint of a Python object graph.

    numpy arrays count their buffer (`nbytes`), containers recurse, and
    both `__dict__`- and `__slots__`-backed objects walk their fields.
    Shared objects are counted once (identity memo), so columnar views
    don't double-bill the arena.
    """
    seen = _seen if _seen is not None else set()
    oid = id(obj)
    if oid in seen:
        return 0
    seen.add(oid)
    if isinstance(obj, np.ndarray):
        # __sizeof__ counts the buffer only for owning arrays; a view's
        # buffer is billed to its base (walked separately if reachable)
        return int(obj.__sizeof__())
    size = sys.getsizeof(obj)
    if isinstance(obj, dict):
        for k, v in obj.items():
            size += deep_sizeof(k, seen) + deep_sizeof(v, seen)
    elif isinstance(obj, (list, tuple, set, frozenset, deque)):
        for item in obj:
            size += deep_sizeof(item, seen)
    elif isinstance(obj, (str, bytes, bytearray, int, float, bool, complex)):
        pass
    else:
        d = getattr(obj, "__dict__", None)
        if d is not None:
            size += deep_sizeof(d, seen)
        for klass in type(obj).__mro__:
            for slot in getattr(klass, "__slots__", ()):
                if slot in ("__dict__", "__weakref__"):
                    continue
                try:
                    size += deep_sizeof(getattr(obj, slot), seen)
                except AttributeError:
                    pass
    return size
