"""The in-payload `autospada` client library (paper §5.1).

Core functionality available to payload code:
  * ``get_signal(name)``        — read the latest value of a vehicle signal
  * ``publish(value)``          — publish a JSON-serializable result
  * ``get_parameters()``        — read the task's parameters document
  * ``cache_state(value)``      — persist intermediate state (survives
                                  client restarts; removed on completion)
  * ``load_state()``            — read previously cached state
  * ``sleep(seconds)``          — cancellation-aware sleep

Two modes, matching §5.1.1:
  * **attached** — wired to a live client's signal/result handlers (the
    containerized production path);
  * **dummy**    — stand-alone: random signal values, publishes print to
    stdout, so any payload runs as an ordinary Python script.

Cancellation: a cooperative flag checked on every API call (the in-process
analogue of `docker stop`'s SIGTERM): raises ``TaskCanceled``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable

import numpy as np


class TaskCanceled(Exception):
    """Raised inside payload code when the task has been canceled."""


class PayloadContext:
    """One task-container's view of the world."""

    def __init__(
        self,
        *,
        get_signal: Callable[[str], float | None],
        publish: Callable[[Any], None],
        parameters: Any = None,
        state_cache: dict[str, Any] | None = None,
        task_key: str = "local",
        cancel_event: threading.Event | None = None,
        clock: Callable[[], float] = time.monotonic,
        get_signal_window: Callable[[str, int], list[float]] | None = None,
        virtual_clock: bool | None = None,
    ):
        self._get_signal = get_signal
        self._get_signal_window = get_signal_window
        self._publish = publish
        self._parameters = parameters
        self._state_cache = state_cache if state_cache is not None else {}
        self._task_key = task_key
        self._cancel = cancel_event or threading.Event()
        self._clock = clock
        # A virtual (simulated) clock means `sleep` must never burn real
        # wall-clock waiting on it — fleet-scale sims inject clocks that
        # only advance when the world pumps. Callers injecting a wrapped
        # wall clock should pass virtual_clock=False explicitly; the
        # default recognizes the stdlib wall clocks by identity.
        if virtual_clock is None:
            virtual_clock = clock not in (
                time.monotonic, time.time, time.perf_counter
            )
        self._virtual_clock = virtual_clock
        self.published_count = 0

    # -- cancellation ------------------------------------------------- #
    def _check_cancel(self) -> None:
        if self._cancel.is_set():
            raise TaskCanceled(self._task_key)

    def cancel(self) -> None:
        self._cancel.set()

    # -- the user-facing API ------------------------------------------ #
    def get_signal(self, name: str) -> float | None:
        self._check_cancel()
        return self._get_signal(name)

    def get_signal_window(self, name: str, k: int) -> list[float]:
        """Last `k` observed values of a signal, oldest first — the input
        to on-vehicle windowed analytics. Sources without history fall
        back to a single latest-value sample."""
        self._check_cancel()
        if self._get_signal_window is not None:
            return [float(v) for v in self._get_signal_window(name, k)]
        v = self._get_signal(name)
        return [] if v is None else [float(v)]

    def publish(self, value: Any) -> None:
        self._check_cancel()
        json.dumps(value, default=str)  # enforce JSON-serializability
        self._publish(value)
        self.published_count += 1

    def get_parameters(self) -> Any:
        self._check_cancel()
        return self._parameters

    def cache_state(self, value: Any) -> None:
        self._check_cancel()
        self._state_cache[self._task_key] = value

    def load_state(self) -> Any:
        self._check_cancel()
        return self._state_cache.get(self._task_key)

    def clear_state(self) -> None:
        self._state_cache.pop(self._task_key, None)

    def sleep(self, seconds: float) -> None:
        """Cancellation-aware sleep; in simulation the clock is virtual.

        With a wall clock this naps in small slices so cancellation stays
        responsive. With an injected virtual clock it must NOT nap for
        real — a simulated 5 s sleep across 1000 vehicles would otherwise
        burn actual wall-clock — so it only yields the GIL between
        cancellation checks while waiting for the simulation to advance
        the clock."""
        deadline = self._clock() + seconds
        while self._clock() < deadline:
            self._check_cancel()
            if self._virtual_clock:
                time.sleep(0)  # yield only; virtual time is free
            else:
                time.sleep(min(0.002, max(0.0, deadline - self._clock())))

    def time(self) -> float:
        return self._clock()


def dummy_context(seed: int = 0, parameters: Any = None) -> PayloadContext:
    """Paper §5.1.1: 'By default, the autospada library acts as a dummy
    library that returns random values for any signal and prints messages
    to standard output when side effects occur.'"""
    rng = np.random.default_rng(seed)

    def get_signal(name: str) -> float:
        return float(rng.standard_normal())

    def get_signal_window(name: str, k: int) -> list[float]:
        return [float(v) for v in rng.standard_normal(max(0, int(k)))]

    def publish(value: Any) -> None:
        print(f"[autospada dummy] publish: {json.dumps(value, default=str)}")

    return PayloadContext(
        get_signal=get_signal,
        get_signal_window=get_signal_window,
        publish=publish,
        parameters=parameters,
    )
