"""The in-payload `autospada` client library (paper §5.1).

Core functionality available to payload code:
  * ``get_signal(name)``        — read the latest value of a vehicle signal
  * ``publish(value)``          — publish a JSON-serializable result
  * ``get_parameters()``        — read the task's parameters document
  * ``cache_state(value)``      — persist intermediate state (survives
                                  client restarts; removed on completion)
  * ``load_state()``            — read previously cached state
  * ``sleep(seconds)``          — cancellation-aware sleep

Two modes, matching §5.1.1:
  * **attached** — wired to a live client's signal/result handlers (the
    containerized production path);
  * **dummy**    — stand-alone: random signal values, publishes print to
    stdout, so any payload runs as an ordinary Python script.

Cancellation: a cooperative flag checked on every API call (the in-process
analogue of `docker stop`'s SIGTERM): raises ``TaskCanceled``.
"""
from __future__ import annotations

import json
import threading
import time
from typing import Any, Callable

import numpy as np


class TaskCanceled(Exception):
    """Raised inside payload code when the task has been canceled."""


#: The frozen user-facing `autospada` contract (paper §5.1). Payload code
#: may rely on exactly these names — in every execution mode (attached,
#: dummy, containerized) and every future release; additions are
#: deliberate API changes and removals are breaking. The sandbox binds
#: the `PayloadContext` instance itself as the `autospada` module, so
#: `autospada.__all__` inside a payload enumerates this same tuple.
#: tests/test_api_surface.py pins it against accidental drift.
AUTOSPADA_API = (
    "get_signal",
    "get_signal_window",
    "get_signal_sketch",
    "publish",
    "get_parameters",
    "cache_state",
    "load_state",
    "clear_state",
    "sleep",
    "time",
)

__all__ = ["AUTOSPADA_API", "PayloadContext", "TaskCanceled", "dummy_context"]


class PayloadContext:
    """One task-container's view of the world.

    The public methods named in `AUTOSPADA_API` are the whole payload
    surface. Two cross-cutting guarantees every method shares:

    * **determinism** — attached contexts read simulated state (signal
      plane rows, parameter documents, the injected clock) that is a pure
      function of the simulation config and tick; a payload that calls
      only this API is replayable bit-for-bit at a fixed seed.
    * **virtual clocks** — `sleep`/`time` run against the injected clock;
      under a simulated (virtual) clock, `sleep` never burns wall time
      and `time` advances only when the world pumps.

    `cancel()` is deliberately *not* part of the payload surface: it is
    the host-side control edge (the `docker stop` analogue).
    """

    #: `import autospada` resolves to this object inside payloads, so the
    #: conventional `__all__` lookup works there too
    __all__ = AUTOSPADA_API

    def __init__(
        self,
        *,
        get_signal: Callable[[str], float | None],
        publish: Callable[[Any], None],
        parameters: Any = None,
        state_cache: dict[str, Any] | None = None,
        task_key: str = "local",
        cancel_event: threading.Event | None = None,
        clock: Callable[[], float] = time.monotonic,
        get_signal_window: Callable[[str, int], list[float]] | None = None,
        get_signal_sketch: Callable[..., dict | None] | None = None,
        virtual_clock: bool | None = None,
    ):
        self._get_signal = get_signal
        self._get_signal_window = get_signal_window
        self._get_signal_sketch = get_signal_sketch
        self._publish = publish
        self._parameters = parameters
        self._state_cache = state_cache if state_cache is not None else {}
        self._task_key = task_key
        self._cancel = cancel_event or threading.Event()
        self._clock = clock
        # A virtual (simulated) clock means `sleep` must never burn real
        # wall-clock waiting on it — fleet-scale sims inject clocks that
        # only advance when the world pumps. Callers injecting a wrapped
        # wall clock should pass virtual_clock=False explicitly; the
        # default recognizes the stdlib wall clocks by identity.
        if virtual_clock is None:
            virtual_clock = clock not in (
                time.monotonic, time.time, time.perf_counter
            )
        self._virtual_clock = virtual_clock
        self.published_count = 0

    # -- cancellation ------------------------------------------------- #
    def _check_cancel(self) -> None:
        if self._cancel.is_set():
            raise TaskCanceled(self._task_key)

    def cancel(self) -> None:
        self._cancel.set()

    # -- the user-facing API (AUTOSPADA_API — the frozen contract) ----- #
    def get_signal(self, name: str) -> float | None:
        """Latest value of a vehicle signal, or None if unknown. Attached
        contexts read the deterministic signal plane (a pure function of
        scenario, seed, and tick); the dummy context draws seeded
        randoms."""
        self._check_cancel()
        return self._get_signal(name)

    def get_signal_window(self, name: str, k: int) -> list[float]:
        """Last `k` *observed* values of a signal, oldest first — the
        input to on-vehicle windowed analytics. "Observed" means ticks
        the vehicle was powered on: offline ticks record nothing, so
        the list may be shorter than `k` (as may a vehicle younger than
        `k` ticks, or a history ring smaller than `k`). Unknown signals
        return ``[]``. Sources without history fall back to a single
        latest-value sample; attached contexts serve the signal plane's
        ring, synced to the host lazily on first read."""
        self._check_cancel()
        if self._get_signal_window is not None:
            return [float(v) for v in self._get_signal_window(name, k)]
        v = self._get_signal(name)
        return [] if v is None else [float(v)]

    def get_signal_sketch(
        self,
        name: str,
        k: int,
        *,
        bins: int = 16,
        lo: float = 0.0,
        hi: float = 12.0,
        quantile_k: int = 32,
    ) -> dict:
        """Compact mergeable sketch of the last `k` observed values of a
        signal: ``{"count", "mean", "m2", "hist", "qsk"}`` — sample
        count, float32 Welford mean and sum of squared deviations, a
        `bins`-bin [lo, hi) histogram (outliers clipped to the edge
        bins), and `quantile_k` equal-weight ranked values (a KLL-style
        quantile summary; empty when count is 0). Sketches from many
        vehicles merge exactly (`kernels.ops.merge_moments` /
        `merge_histograms` / `merge_quantile_sketches`), which is the
        point: only sketch-sized results leave the vehicle, never the
        window itself.

        Exactly the observations `get_signal_window(name, k)` would
        return are folded — offline-tick masking and short histories
        included. Plane-attached contexts answer from one fused fleet-
        wide device fold over the signal ring (cached per tick, the
        ring never syncs to the host); every other source folds the
        window through the identical float32 reference formula
        (`kernels.sketch.sketch_reference`), so the result is
        bit-for-bit the same either way."""
        self._check_cancel()
        if self._get_signal_sketch is not None:
            sk = self._get_signal_sketch(
                name, int(k), int(bins), float(lo), float(hi), int(quantile_k)
            )
            if sk is not None:
                return sk
        from repro.kernels.sketch import SketchSpec, sketch_reference

        spec = SketchSpec(
            window=max(1, int(k)), bins=int(bins), lo=float(lo), hi=float(hi),
            quantile_k=int(quantile_k),
        )
        return sketch_reference(self.get_signal_window(name, int(k)), spec)

    def publish(self, value: Any) -> None:
        """Publish a JSON-serializable result to the platform. Delivery
        is at-least-once (QoS 1): the server deduplicates by sequence
        number, so publishing is idempotent end to end."""
        self._check_cancel()
        json.dumps(value, default=str)  # enforce JSON-serializability
        self._publish(value)
        self.published_count += 1

    def get_parameters(self) -> Any:
        """The task's immutable Parameters document (None if the task
        carries none). Identical on every read and every re-run."""
        self._check_cancel()
        return self._parameters

    def cache_state(self, value: Any) -> None:
        """Persist intermediate state under the task's key: it survives
        client restarts and is removed when the task completes."""
        self._check_cancel()
        self._state_cache[self._task_key] = value

    def load_state(self) -> Any:
        """Previously cached state for this task, or None."""
        self._check_cancel()
        return self._state_cache.get(self._task_key)

    def clear_state(self) -> None:
        """Drop this task's cached state (idempotent)."""
        self._state_cache.pop(self._task_key, None)

    def sleep(self, seconds: float) -> None:
        """Cancellation-aware sleep; in simulation the clock is virtual.

        With a wall clock this naps in small slices so cancellation stays
        responsive. With an injected virtual clock it must NOT nap for
        real — a simulated 5 s sleep across 1000 vehicles would otherwise
        burn actual wall-clock — so it only yields the GIL between
        cancellation checks while waiting for the simulation to advance
        the clock."""
        deadline = self._clock() + seconds
        while self._clock() < deadline:
            self._check_cancel()
            if self._virtual_clock:
                time.sleep(0)  # yield only; virtual time is free
            else:
                time.sleep(min(0.002, max(0.0, deadline - self._clock())))

    def time(self) -> float:
        """The task's clock. Under a virtual (simulated) clock this is
        logical time that advances only when the world pumps — never
        wall time — so payload timing logic stays deterministic."""
        return self._clock()


def dummy_context(seed: int = 0, parameters: Any = None) -> PayloadContext:
    """Paper §5.1.1: 'By default, the autospada library acts as a dummy
    library that returns random values for any signal and prints messages
    to standard output when side effects occur.'"""
    rng = np.random.default_rng(seed)

    def get_signal(name: str) -> float:
        return float(rng.standard_normal())

    def get_signal_window(name: str, k: int) -> list[float]:
        return [float(v) for v in rng.standard_normal(max(0, int(k)))]

    def publish(value: Any) -> None:
        print(f"[autospada dummy] publish: {json.dumps(value, default=str)}")

    return PayloadContext(
        get_signal=get_signal,
        get_signal_window=get_signal_window,
        publish=publish,
        parameters=parameters,
    )
