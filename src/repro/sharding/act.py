"""Explicit activation sharding constraints (hillclimb H3.2).

GSPMD's sharding propagation is heuristic, not cost-optimal: with FSDP'd
weights it can decide to *unshard the global batch* (34 GB activation
all-gathers per layer on jamba-398B) instead of the 50 MB per-layer weight
gather FSDP intends. Pinning the batch axis of the residual stream at
every layer boundary removes that degree of freedom — the partitioner is
then forced into the weight-gather resolution.

The constraint axes are process-global, set by the launcher (the model
code stays mesh-agnostic); outside a mesh context this is a no-op, so
tests and single-device examples are untouched.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: tuple[str, ...] | None = None
_MODEL_AXIS: tuple[str, int] | None = None  # (name, size)


def set_batch_axes(axes: tuple[str, ...] | None) -> None:
    global _BATCH_AXES
    _BATCH_AXES = axes


def set_model_axis(name: str | None, size: int = 0) -> None:
    global _MODEL_AXIS
    _MODEL_AXIS = (name, size) if name else None


def get_batch_axes() -> tuple[str, ...] | None:
    return _BATCH_AXES


def constrain_batch(x: jax.Array) -> jax.Array:
    """Pin dim0 = batch to the configured axes; other dims unconstrained."""
    if _BATCH_AXES is None or x.ndim < 2:
        return x
    if x.shape[0] == 1:  # unshardable batch (long_500k)
        return x
    spec = [None] * x.ndim
    spec[0] = _BATCH_AXES
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:  # no mesh context (CPU tests) — no-op
        return x


def constrain_expert_batch(x: jax.Array) -> jax.Array:
    """Pin (B, E, C, d)-shaped dispatched MoE tensors: batch on the data
    axes AND experts on the model axis (expert parallelism), so neither
    the dispatch gather nor its backward can unshard either dim
    (hillclimb H3.3)."""
    if _BATCH_AXES is None or x.ndim < 3:
        return x
    spec = [None] * x.ndim
    if x.shape[0] > 1:
        spec[0] = _BATCH_AXES
    if _MODEL_AXIS is not None and _MODEL_AXIS[1] and x.shape[1] % _MODEL_AXIS[1] == 0:
        spec[1] = _MODEL_AXIS[0]
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
