"""Sharding planner: rule-based PartitionSpecs with divisibility fallbacks.

Given an ArchConfig and a mesh, produce NamedShardings for every leaf of
the param pytree, optimizer state, input batch and decode cache. The rules
implement the policy documented in DESIGN.md §5:

* Megatron-style tensor parallelism on the `model` axis wherever the
  natural dimension is divisible (head-boundary-safe for attention);
* graceful fallbacks when it is not (gemma3's 4 heads, mixtral's 8
  experts, xlstm's width): replicate or shard an alternative dimension —
  never crash, never silently mis-shard;
* optional FSDP (`fsdp=True`, auto-enabled for >=20B-param configs):
  params/moments additionally sharded over `data` on a secondary
  dimension; XLA inserts the per-layer all-gathers (ZeRO-3 semantics);
* serve mode: weights may also use the `data` axis (requests are
  replicated reads — there is no gradient to sync), which is what lets
  141B/398B checkpoints fit 256 x 16 GB chips during decode;
* KV caches: batch on `data` when divisible; heads on `model` when
  divisible, else cache *sequence* on `model` (flash-decoding layout),
  else replicate.

The planner is pure metadata: it never touches device buffers.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import ArchConfig


# --------------------------------------------------------------------- #
# helpers                                                               #
# --------------------------------------------------------------------- #
def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 0


def _dp_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes that carry the batch: ('pod','data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in _dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _try(spec: list, dim: int, size: int, axis: str, mesh: Mesh, used: set) -> bool:
    """Assign `axis` to `dim` if divisible and axis unused."""
    asize = _axis_size(mesh, axis)
    if asize and size % asize == 0 and axis not in used:
        spec[dim] = axis
        used.add(axis)
        return True
    return False


def _widen(spec: list, dim: int, size: int, mesh: Mesh, used: set) -> bool:
    """Extend a 'model'-sharded dim to ('model','data'): FSDP/serve weight
    storage sharding that keeps contraction dims whole, so GSPMD's only
    sane resolution is the cheap per-layer weight all-gather — never the
    batch-gather + giant partial-sum all-reduce (hillclimb H3.1)."""
    d_ax = _axis_size(mesh, "data")
    m_ax = _axis_size(mesh, "model")
    if (
        spec[dim] == "model"
        and d_ax
        and "data" not in used
        and size % (d_ax * m_ax) == 0
    ):
        spec[dim] = ("model", "data")
        used.add("data")
        return True
    return False


def _mk(spec: list) -> P:
    return P(*spec)


# --------------------------------------------------------------------- #
# parameter rules                                                       #
# --------------------------------------------------------------------- #
def _param_spec(
    path: tuple[str, ...],
    shape: tuple[int, ...],
    cfg: ArchConfig,
    mesh: Mesh,
    *,
    fsdp: bool,
    serve: bool,
) -> P:
    name = path[-1]
    stacked = "groups" in path  # leading `repeats` dim
    base = 1 if stacked else 0
    nd = len(shape)
    spec: list = [None] * nd
    used: set[str] = set()
    model = _axis_size(mesh, "model")

    def dim_size(i: int) -> int:
        return shape[base + i]

    def sd(i: int) -> int:  # absolute dim index
        return base + i

    in_attn = "mixer" in path and name in (
        "wq", "wk", "wv", "wo", "wz", "w_igate", "w_fgate",
    )
    in_moe = name in ("router", "w_gate", "w_up", "w_down") and (
        nd - base == 3 or name == "router"
    )

    if name == "embed":
        _try(spec, sd(0), dim_size(0), "model", mesh, used)  # vocab
        if fsdp or serve:
            _widen(spec, sd(0), dim_size(0), mesh, used) or _try(
                spec, sd(1), dim_size(1), "data", mesh, used
            )
    elif name == "lm_head":
        _try(spec, sd(1), dim_size(1), "model", mesh, used)  # vocab (xK)
        if fsdp or serve:
            _widen(spec, sd(1), dim_size(1), mesh, used) or _try(
                spec, sd(0), dim_size(0), "data", mesh, used
            )
    elif in_attn and name in ("wq", "wz"):
        # output is heads*head_dim: shard only on head boundaries
        if model and cfg.n_heads % model == 0:
            _try(spec, sd(1), dim_size(1), "model", mesh, used)
        if fsdp or serve:
            # widen the model-sharded dim; if the tensor could not use the
            # model axis at all (head-count fallback), store it data-
            # sharded instead of fully replicated — activations are pinned
            # (sharding/act.py), so the batch-unshard pathology is blocked.
            _widen(spec, sd(1), dim_size(1), mesh, used) or _try(
                spec, sd(0), dim_size(0), "data", mesh, used
            )
    elif in_attn and name in ("wk", "wv"):
        if model and cfg.n_kv_heads % model == 0:
            _try(spec, sd(1), dim_size(1), "model", mesh, used)
        if fsdp or serve:
            _widen(spec, sd(1), dim_size(1), mesh, used) or _try(
                spec, sd(0), dim_size(0), "data", mesh, used
            )
    elif in_attn and name == "wo":
        if model and cfg.n_heads % model == 0:
            _try(spec, sd(0), dim_size(0), "model", mesh, used)
        if fsdp or serve:
            _widen(spec, sd(0), dim_size(0), mesh, used) or _try(
                spec, sd(1), dim_size(1), "data", mesh, used
            )
    elif in_attn:  # w_igate / w_fgate: tiny
        pass
    elif in_moe and name == "router":
        pass  # (d, E) tiny, replicated
    elif in_moe and name in ("w_gate", "w_up"):
        # (E, d, f)
        if model and cfg.moe_experts % model == 0:
            _try(spec, sd(0), dim_size(0), "model", mesh, used)
            if fsdp or serve:
                _try(spec, sd(2), dim_size(2), "data", mesh, used)
        else:
            _try(spec, sd(2), dim_size(2), "model", mesh, used)
            if fsdp or serve:
                _widen(spec, sd(2), dim_size(2), mesh, used)
    elif in_moe and name == "w_down":
        # (E, f, d)
        if model and cfg.moe_experts % model == 0:
            _try(spec, sd(0), dim_size(0), "model", mesh, used)
            if fsdp or serve:
                _try(spec, sd(1), dim_size(1), "data", mesh, used)
        else:
            _try(spec, sd(1), dim_size(1), "model", mesh, used)
            if fsdp or serve:
                _widen(spec, sd(1), dim_size(1), mesh, used)
    elif name in ("w_gate", "w_up"):  # dense SwiGLU (d, ff)
        _try(spec, sd(1), dim_size(1), "model", mesh, used)
        if fsdp or serve:
            _widen(spec, sd(1), dim_size(1), mesh, used)
    elif name == "w_down":  # dense SwiGLU (ff, d)
        _try(spec, sd(0), dim_size(0), "model", mesh, used)
        if fsdp or serve:
            _widen(spec, sd(0), dim_size(0), mesh, used)
    elif name == "in_proj":  # mamba (d, 2*inner)
        _try(spec, sd(1), dim_size(1), "model", mesh, used)
        if fsdp or serve:
            _widen(spec, sd(1), dim_size(1), mesh, used)
    elif name == "conv_w":  # (K, inner)
        _try(spec, sd(1), dim_size(1), "model", mesh, used)
    elif name in ("conv_b", "dt_bias", "D"):  # (inner,)
        _try(spec, sd(0), dim_size(0), "model", mesh, used)
    elif name in ("x_proj", "A_log"):  # (inner, ...)
        _try(spec, sd(0), dim_size(0), "model", mesh, used)
    elif name == "dt_proj":  # (dt_rank, inner)
        _try(spec, sd(1), dim_size(1), "model", mesh, used)
    elif name == "out_proj":  # mamba (inner, d)
        _try(spec, sd(0), dim_size(0), "model", mesh, used)
        if fsdp or serve:
            _widen(spec, sd(0), dim_size(0), mesh, used)
    elif name == "w_in":  # slstm (d, 4d) — gate/head boundary: replicate
        if fsdp or serve:
            _try(spec, sd(0), dim_size(0), "data", mesh, used)
    elif name in ("r_z", "r_i", "r_f", "r_o"):
        pass
    elif name in ("r_z", "r_i", "r_f", "r_o"):  # slstm (H, D, D)
        pass
    # norms / biases / scalars: replicated
    return _mk(spec)


def param_shardings(
    cfg: ArchConfig,
    params_shapes: Any,  # pytree of ShapeDtypeStruct
    mesh: Mesh,
    *,
    fsdp: bool | None = None,
    serve: bool = False,
) -> Any:
    leaves = jax.tree.leaves(params_shapes)
    total_bytes = sum(x.size * jnp.dtype(x.dtype).itemsize for x in leaves)
    if fsdp is None:
        fsdp = total_bytes > 4e9 * _axis_size(mesh, "model")  # >4GB/chip
    if serve:
        # 2D weight sharding only when the model axis alone cannot hold
        # the weights (<=8GB/chip budget): small archs keep weights
        # model-sharded + data-replicated, so decode never gathers them
        # (hillclimb H2/H3 — see EXPERIMENTS.md §Perf).
        serve = total_bytes > 8e9 * _axis_size(mesh, "model")

    def one(path, leaf):
        keys = tuple(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        spec = _param_spec(
            keys, leaf.shape, cfg, mesh, fsdp=fsdp, serve=serve
        )
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def opt_shardings(param_sh: Any, opt_shapes: Any, mesh: Mesh) -> Any:
    """Moments mirror their parameter's sharding; scalars replicated."""
    def like(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        # path = ('m'|'v', *param_path) — strip the first key
        sub = param_sh
        for p in path[1:]:
            key = p.key if hasattr(p, "key") else p.idx
            sub = sub[key]
        return sub

    return jax.tree_util.tree_map_with_path(like, opt_shapes)


# --------------------------------------------------------------------- #
# batch + cache rules                                                   #
# --------------------------------------------------------------------- #
def batch_shardings(
    batch_shapes: Any, mesh: Mesh, *, replicate: bool = False
) -> Any:
    """replicate=True: leave the batch unsharded — used for wide-serve
    decode where the data axis is spent on weight storage and activations
    are tiny (B x 1 x d)."""
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)

    def one(leaf):
        spec: list = [None] * leaf.ndim
        if (
            not replicate
            and leaf.ndim >= 1
            and leaf.shape[0] % dpn == 0
            and leaf.shape[0] > 0
        ):
            spec[0] = dp
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cfg: ArchConfig, cache_shapes: Any, mesh: Mesh) -> Any:
    dp = _dp_axes(mesh)
    dpn = _dp_size(mesh)
    model = _axis_size(mesh, "model")

    def one(path, leaf):
        keys = [p.key if hasattr(p, "key") else str(getattr(p, "idx", p)) for p in path]
        name = keys[-1] if keys else ""
        if leaf.ndim == 0:  # position scalar
            return NamedSharding(mesh, P())
        spec: list = [None] * leaf.ndim
        used: set[str] = set()
        # dim 0 is the stacked `repeats` axis; dim 1 is batch
        if leaf.ndim >= 2 and leaf.shape[1] % dpn == 0:
            spec[1] = dp
            used.add("data")
            used.add("pod")
        wide = spec[1] is None  # batch unshardable: use every axis we can
        if name in ("k", "v") and leaf.ndim == 5:
            # (repeats, B, L, KV, hd)
            if model and leaf.shape[3] % model == 0 and not wide:
                spec[3] = "model"
            elif wide and model and leaf.shape[2] % (dpn * model) == 0:
                spec[2] = (*dp, "model")  # 2D sequence-sharded cache
            elif model and leaf.shape[2] % model == 0:
                spec[2] = "model"  # sequence-sharded cache
        elif name in ("ssm",) and leaf.ndim == 4:  # (r, B, inner, state)
            if wide and model and leaf.shape[2] % (dpn * model) == 0:
                spec[2] = (*dp, "model")
            else:
                _try(spec, 2, leaf.shape[2], "model", mesh, used)
        elif name == "conv" and leaf.ndim == 4:  # (r, B, K-1, inner)
            _try(spec, 3, leaf.shape[3], "model", mesh, used)
        elif name == "C" and leaf.ndim == 5:  # (r, B, H, D, D)
            _try(spec, 3, leaf.shape[3], "model", mesh, used)
        elif name in ("n", "c", "h", "m") and leaf.ndim == 4:  # (r, B, H, D)
            _try(spec, 3, leaf.shape[3], "model", mesh, used)
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def describe(sh_tree: Any) -> dict[str, str]:
    """Flat {path: spec} map for logging/EXPERIMENTS.md."""
    out = {}

    def one(path, sh):
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        out[key] = str(sh.spec)

    jax.tree_util.tree_map_with_path(one, sh_tree)
    return out
