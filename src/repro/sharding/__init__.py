"""Sharding: planner (PartitionSpec rules) + act (activation constraints).

Import submodules directly (`from repro.sharding import planner`) — this
package init stays import-free to avoid models<->planner cycles.
"""
