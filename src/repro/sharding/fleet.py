"""Fleet-plane sharding: the client-axis mesh and its PartitionSpecs.

The model planner (`repro.sharding.planner`) shards *parameter* pytrees
over a 2-D ``(data, model)`` mesh. The fleet signal plane has a much
simpler layout problem: every array is client-major — ``values`` is
``(n_clients, n_signals)``, the history ring is ``(history, n_clients,
n_signals)``, the offline mask is ``(n_clients,)`` — and every per-tick
operation is elementwise per client row. So the whole plane shards on ONE
axis, ``clients``, and the drive-cycle step partitions with zero
collectives: each device advances only its own row shard.

Like the planner, everything here is pure metadata (meshes and
NamedShardings); nothing touches device buffers.
"""
from __future__ import annotations

from typing import Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: the one mesh axis the fleet plane shards over
CLIENT_AXIS = "clients"


def client_mesh(devices: Sequence[jax.Device] | None = None) -> Mesh:
    """A 1-D mesh over every available device (or an explicit subset),
    with the single ``clients`` axis the plane arrays shard on. Under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` this yields 8
    simulated CPU devices — the CI multi-device lane."""
    devs = list(jax.devices() if devices is None else devices)
    return Mesh(np.array(devs), (CLIENT_AXIS,))


def device_count(mesh: Mesh) -> int:
    return int(mesh.shape[CLIENT_AXIS])


def round_up_clients(n: int, mesh: Mesh) -> int:
    """Round a client capacity up to a multiple of the device count, so a
    geometric capacity double always lands on an evenly divisible layout:
    every device keeps whole rows and growth never forces a resharding
    collective on the hot tick path."""
    d = device_count(mesh)
    return max(d, -(-int(n) // d) * d)


def values_sharding(mesh: Mesh) -> NamedSharding:
    """``(n_clients, n_signals)`` — rows split across devices."""
    return NamedSharding(mesh, P(CLIENT_AXIS, None))


def ring_sharding(mesh: Mesh) -> NamedSharding:
    """``(history, n_clients, n_signals)`` — the ring slot axis stays
    whole on every device (slot writes are per-device local); the client
    axis splits."""
    return NamedSharding(mesh, P(None, CLIENT_AXIS, None))


def mask_sharding(mesh: Mesh) -> NamedSharding:
    """``(n_clients,)`` offline mask — aligned with the values rows."""
    return NamedSharding(mesh, P(CLIENT_AXIS))


def replicated(mesh: Mesh) -> NamedSharding:
    """Scalars (the tick counter) are replicated."""
    return NamedSharding(mesh, P())
