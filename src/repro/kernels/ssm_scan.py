"""Pallas TPU selective-scan (Mamba) kernel.

TPU adaptation of the CUDA selective-scan: instead of warp-level parallel
prefix sums, we block the *inner* (channel) dimension across the grid —
channels are embarrassingly parallel in the SSM recurrence — and walk the
sequence in VMEM-resident chunks, carrying the (bi, state) hidden state in
scratch across chunk steps. Per time step the update is a fused
elementwise+reduction over a (bi, state) tile, which maps onto the VPU's
8x128 lanes; there is no matmul, so the MXU is untouched (the surrounding
projections feed it instead).

grid = (B, n_inner_blocks, n_chunks); last dim sequential (`arbitrary`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssm_kernel(
    dt_ref,  # (1, chunk, bi)
    b_ref,  # (1, chunk, state)
    c_ref,  # (1, chunk, state)
    x_ref,  # (1, chunk, bi)
    a_ref,  # (bi, state)
    y_ref,  # (1, chunk, bi)
    h_scr,  # (bi, state) f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros(h_scr.shape, jnp.float32)

    a = a_ref[...].astype(jnp.float32)  # (bi, state)

    def step(t, h):
        dt_t = dt_ref[0, t].astype(jnp.float32)  # (bi,)
        x_t = x_ref[0, t].astype(jnp.float32)  # (bi,)
        b_t = b_ref[0, t].astype(jnp.float32)  # (state,)
        c_t = c_ref[0, t].astype(jnp.float32)  # (state,)
        abar = jnp.exp(dt_t[:, None] * a)  # (bi, state)
        bx = (dt_t * x_t)[:, None] * b_t[None, :]
        h = abar * h + bx
        y_ref[0, t] = jnp.sum(h * c_t[None, :], axis=-1).astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


@functools.partial(
    jax.jit, static_argnames=("block_inner", "chunk", "interpret")
)
def ssm_scan(
    dt: jax.Array,  # (B, S, inner) f32
    Bm: jax.Array,  # (B, S, state) f32
    Cm: jax.Array,  # (B, S, state) f32
    x: jax.Array,  # (B, S, inner)
    A: jax.Array,  # (inner, state) f32
    *,
    block_inner: int = 512,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (B, S, inner) f32. (Final state is recomputed by the
    caller's prefill path when needed — the kernel serves the train path.)
    """
    B, S, inner = dt.shape
    state = Bm.shape[-1]
    block_inner = min(block_inner, inner)
    chunk = min(chunk, S)
    assert inner % block_inner == 0 and S % chunk == 0
    nb, nc = inner // block_inner, S // chunk

    kernel = functools.partial(_ssm_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=(B, nb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_inner), lambda b, ib, ci: (b, ci, ib)),
            pl.BlockSpec((1, chunk, state), lambda b, ib, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, state), lambda b, ib, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, block_inner), lambda b, ib, ci: (b, ci, ib)),
            pl.BlockSpec((block_inner, state), lambda b, ib, ci: (ib, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, chunk, block_inner), lambda b, ib, ci: (b, ci, ib)
        ),
        out_shape=jax.ShapeDtypeStruct((B, S, inner), jnp.float32),
        scratch_shapes=[_vmem((block_inner, state), jnp.float32)],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(dt, Bm, Cm, x, A)
    return y


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        )
    except Exception:  # pragma: no cover
        return None
