"""Fused windowed-sketch kernels over the fleet signal ring.

The streaming-analytics workload folds each vehicle's last-`W`
observations into a compact sketch — Welford moments, a fixed-bin
histogram, and a mergeable KLL-style quantile summary. The legacy path
(`ANALYTICS_PAYLOAD`) does that per vehicle in a sandboxed Python loop
after `get_signal_window` has synced the whole history ring
device→host. This module folds the **entire fleet at once, in place on
the ring's device shards**: one `(3 + bins + K, capacity)` f32 result
leaves the device, the ring never does.

Bit-for-bit parity with the per-vehicle Python fold is load-bearing
(the payload path stays the oracle), which dictates three non-obvious
choices:

* The Welford scan carries the *pending* product ``d * (v - mean)`` as
  a separate element and adds it one step late. A plain
  ``m2 + d * (v - mean)`` lets XLA:CPU/LLVM contract the multiply-add
  into a single-rounding FMA, which diverges from the numpy scalar
  loop in the sandbox; routing the product through the scan carry (a
  phi node) blocks the contraction. Verified exact over masked and
  unmasked trials.
* Histogram binning compares samples against precomputed f32 interior
  edges (``x >= edge_j`` counts) instead of dividing by the bin width —
  comparisons are exact, division is not. The edge formula lives in
  `SketchSpec.edges` and is shared with the payload text.
* The quantile summary is pure selection: K order statistics at
  integer ranks of the f32-sorted window, no arithmetic on samples, so
  device and numpy agree bitwise. Rank error after merging is bounded
  by ``total / (2K)`` (see `merge_quantile_sketches` in kernels.ops).

Dispatch follows kernels/ops.py: TPU → the Pallas kernel, anything
else → the jit'd `lax.scan` twin (or the Pallas kernel in interpret
mode for kernel-parity tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Iterable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclasses.dataclass(frozen=True)
class SketchSpec:
    """Shape of a windowed sketch. Frozen + hashable so planes can key
    their per-tick fleet-sketch cache on it."""

    window: int = 64
    bins: int = 16
    lo: float = 0.0
    hi: float = 12.0
    quantile_k: int = 32

    def __post_init__(self):
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.bins < 1:
            raise ValueError(f"bins must be >= 1, got {self.bins}")
        if self.quantile_k < 1:
            raise ValueError(f"quantile_k must be >= 1, got {self.quantile_k}")

    @property
    def dim(self) -> int:
        """Rows of the fused device output: count, mean, m2, hist, quantiles."""
        return 3 + self.bins + self.quantile_k

    def edges(self) -> np.ndarray:
        """Interior bin edges, f32. Samples are binned by counting
        ``x >= edge_j`` — exact comparisons, matching the clip semantics
        of the original division-based binning (x < lo → bin 0,
        x >= hi → last bin, x == edge_j → bin j)."""
        width = (self.hi - self.lo) / self.bins
        return (self.lo + width * np.arange(1, self.bins)).astype(np.float32)


def sketch_reference(xs: Iterable[float], spec: SketchSpec) -> dict:
    """Per-vehicle numpy oracle: the exact fold `ANALYTICS_PAYLOAD` runs
    in the sandbox (f32 Welford, edge-comparison binning, integer-rank
    quantile selection). `compute_sketches` must match it bit-for-bit."""
    x = np.asarray(list(xs), dtype=np.float32)
    count = int(x.shape[0])
    c = np.float32(0.0)
    one = np.float32(1.0)
    mean = np.float32(0.0)
    m2 = np.float32(0.0)
    for v in x:
        c = c + one
        d = v - mean
        mean = mean + d / c
        m2 = m2 + d * (v - mean)
    edges = spec.edges()
    if count:
        idx = (x[:, None] >= edges[None, :]).sum(axis=1)
        hist = np.bincount(idx, minlength=spec.bins)
        xs_sorted = np.sort(x)
        K = spec.quantile_k
        ranks = np.minimum((2 * np.arange(K) + 1) * count // (2 * K), count - 1)
        qsk = [float(v) for v in xs_sorted[ranks]]
    else:
        hist = np.zeros((spec.bins,), np.int64)
        qsk = []
    return {
        "count": count,
        "mean": float(mean),
        "m2": float(m2),
        "hist": [int(v) for v in hist],
        "qsk": qsk,
    }


@dataclasses.dataclass(frozen=True)
class FleetSketches:
    """Host-side container for one fleet-wide sketch call."""

    spec: SketchSpec
    counts: np.ndarray  # (n,) int64
    means: np.ndarray   # (n,) f32
    m2s: np.ndarray     # (n,) f32
    hists: np.ndarray   # (n, bins) int64
    qvals: np.ndarray   # (n, quantile_k) f32; NaN rows where count == 0

    @property
    def n_clients(self) -> int:
        return int(self.counts.shape[0])

    def row(self, i: int) -> dict:
        """Payload-shaped dict for vehicle `i` — bit-identical to
        `sketch_reference` over that vehicle's window."""
        c = int(self.counts[i])
        return {
            "count": c,
            "mean": float(self.means[i]),
            "m2": float(self.m2s[i]),
            "hist": [int(v) for v in self.hists[i]],
            "qsk": [] if c == 0 else [float(v) for v in self.qvals[i]],
        }


def sketches_from_device(spec: SketchSpec, out: np.ndarray) -> FleetSketches:
    """Split the fused `(3 + bins + K, n)` device result into typed
    host arrays (counts/hists exact as integers: both are bounded by
    the window length, far inside f32's 2^24 integer range)."""
    nb = spec.bins
    return FleetSketches(
        spec=spec,
        counts=out[0].astype(np.int64),
        means=out[1].copy(),
        m2s=out[2].copy(),
        hists=out[3 : 3 + nb].T.astype(np.int64),
        qvals=out[3 + nb :].T.copy(),
    )


def empty_fleet_sketches(spec: SketchSpec, n: int) -> FleetSketches:
    """Zero-sample sketches for `n` vehicles (unknown signal / empty
    fleet) — `row()` matches `sketch_reference([], spec)`."""
    return FleetSketches(
        spec=spec,
        counts=np.zeros((n,), np.int64),
        means=np.zeros((n,), np.float32),
        m2s=np.zeros((n,), np.float32),
        hists=np.zeros((n, spec.bins), np.int64),
        qvals=np.full((n, spec.quantile_k), np.nan, np.float32),
    )


# --------------------------------------------------------------------- #
# shared fold pieces (identical math in the XLA twin and the kernel)    #
# --------------------------------------------------------------------- #
def _welford_update(carry, v, ok):
    """One masked Welford step with the FMA-blocking pending product."""
    c, m, m2, pend = carry
    m2n = m2 + pend
    cn = c + 1.0
    d = v - m
    mn = m + d / cn
    pn = d * (v - mn)
    return (
        jnp.where(ok, cn, c),
        jnp.where(ok, mn, m),
        jnp.where(ok, m2n, m2),
        jnp.where(ok, pn, pend),
    )


def _edge_hist(x, valid, c, edges):
    """(bins, n) f32 counts from >=-edge comparisons. Exact: counts are
    bounded by the window length."""
    if edges.shape[0] == 0:
        return c[None]
    ge = jnp.where(
        valid[:, None, :] & (x[:, None, :] >= edges[None, :, None]), 1.0, 0.0
    )
    cum = jnp.sum(ge, axis=0)  # (bins-1, n) — count of samples >= each edge
    return jnp.concatenate([c[None] - cum[:1], cum[:-1] - cum[1:], cum[-1:]], axis=0)


def _quantile_ranks(kc, quantile_k):
    """(K, n) int32 ranks: midpoints of K equal-weight blocks, clipped."""
    j = jax.lax.broadcasted_iota(jnp.int32, (quantile_k, 1), 0)
    return jnp.clip(
        ((2 * j + 1) * kc[None, :]) // (2 * quantile_k),
        0,
        jnp.maximum(kc[None, :] - 1, 0),
    )


def _window_block(ring, t, hist_len, col, window):
    """Gather column `col`'s last-`window` ring slots, oldest first, as a
    (W, capacity) block. Positions older than the recorded history are
    NaN, exactly reproducing `FleetSignalPlane.window`'s
    ``k = min(k_requested, hist_len)`` truncation; offline ticks are
    already NaN in the ring itself."""
    hist_cap = ring.shape[0]
    W = min(int(window), hist_cap)
    i = jnp.arange(W, dtype=jnp.int32)
    slots = (t - W + 1 + i) % hist_cap  # jnp % is floor-mod: non-negative
    x = ring[slots, :, col]  # (W, capacity)
    k = jnp.minimum(W, hist_len)
    return jnp.where((i < W - k)[:, None], jnp.nan, x)


# --------------------------------------------------------------------- #
# XLA twin: jit'd lax.scan fold                                         #
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("quantile_k",))
def _fold_xla(x, edges, *, quantile_k):
    """(W, n) time-ordered window (NaN = not observed) -> (dim, n) f32."""
    n = x.shape[1]
    valid = jnp.logical_not(jnp.isnan(x))
    xz = jnp.where(valid, x, 0.0)

    def step(carry, vo):
        v, ok = vo
        return _welford_update(carry, v, ok), None

    zeros = jnp.zeros((n,), jnp.float32)
    (c, m, m2, pend), _ = jax.lax.scan(step, (zeros, zeros, zeros, zeros), (xz, valid))
    m2 = m2 + pend

    hist = _edge_hist(x, valid, c, edges)

    kc = c.astype(jnp.int32)
    idx = _quantile_ranks(kc, quantile_k)
    xs_sorted = jnp.sort(x, axis=0)  # NaNs sort last, matching numpy
    qv = jnp.take_along_axis(xs_sorted, idx, axis=0)
    qv = jnp.where(kc[None, :] > 0, qv, jnp.nan)
    return jnp.concatenate([c[None], m[None], m2[None], hist, qv], axis=0)


@functools.partial(jax.jit, static_argnames=("col", "window", "quantile_k"))
def _ring_sketch_xla(ring, t, hist_len, edges, *, col, window, quantile_k):
    """Fused gather + fold so the ring is consumed where it lives; on a
    sharded ring GSPMD propagates the client-axis sharding through every
    op (all are per-client elementwise/columnwise)."""
    x = _window_block(ring, t, hist_len, col, window)
    return _fold_xla(x, edges, quantile_k=quantile_k)


# --------------------------------------------------------------------- #
# Pallas kernel: one client block per grid step                         #
# --------------------------------------------------------------------- #
def _sketch_kernel(x_ref, xs_ref, e_ref, o_ref, *, quantile_k: int, n_bins: int):
    X = x_ref[...]   # (W, bn) time-ordered window block
    Xs = xs_ref[...]  # (W, bn) same block, sorted along the window axis
    W, bn = X.shape
    valid = jnp.logical_not(jnp.isnan(X))
    Xz = jnp.where(valid, X, 0.0)

    def body(s, carry):
        v = jax.lax.dynamic_index_in_dim(Xz, s, 0, keepdims=False)
        ok = jax.lax.dynamic_index_in_dim(valid, s, 0, keepdims=False)
        return _welford_update(carry, v, ok)

    zeros = jnp.zeros((bn,), jnp.float32)
    c, m, m2, pend = jax.lax.fori_loop(0, W, body, (zeros, zeros, zeros, zeros))
    m2 = m2 + pend

    edges = e_ref[0, : n_bins - 1] if n_bins > 1 else e_ref[0, :0]
    hist = _edge_hist(X, valid, c, edges)

    kc = c.astype(jnp.int32)
    idx = _quantile_ranks(kc, quantile_k)  # (K, bn)
    # One-hot selection of the ranked order statistics. `where` rather
    # than multiply: Xs holds NaN pad lanes and NaN * 0 = NaN.
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, W, 1), 1)
    sel = idx[:, None, :] == pos
    qv = jnp.sum(jnp.where(sel, Xs[None, :, :], 0.0), axis=1)
    qv = jnp.where(kc[None, :] > 0, qv, jnp.nan)
    o_ref[...] = jnp.concatenate([c[None], m[None], m2[None], hist, qv], axis=0)


@functools.partial(
    jax.jit, static_argnames=("quantile_k", "n_bins", "block_clients", "interpret")
)
def _fold_pallas(x, edges, *, quantile_k, n_bins, block_clients, interpret):
    """(W, n) window -> (dim, n) sketches via the Pallas kernel, one
    128-client block per grid step. Clients are padded to a block
    multiple with NaN columns (folded as count-0 rows, sliced off)."""
    W, n = x.shape
    bn = min(block_clients, max(n, 1))
    pad = (-n) % bn
    if pad:
        fill = jnp.full((W, pad), jnp.nan, x.dtype)
        x = jnp.concatenate([x, fill], axis=1)
    xs = jnp.sort(x, axis=0)
    # 2-D edges block (TPU tiles want >= 2-D refs); width-1 dummy when
    # there are no interior edges so the BlockSpec stays non-empty.
    ew = max(1, n_bins - 1)
    e2 = jnp.zeros((1, ew), jnp.float32)
    if n_bins > 1:
        e2 = e2.at[0, :].set(edges)
    dim = 3 + n_bins + quantile_k
    out = pl.pallas_call(
        functools.partial(_sketch_kernel, quantile_k=quantile_k, n_bins=n_bins),
        grid=((n + pad) // bn,),
        in_specs=[
            pl.BlockSpec((W, bn), lambda i: (0, i)),
            pl.BlockSpec((W, bn), lambda i: (0, i)),
            pl.BlockSpec((1, ew), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((dim, bn), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((dim, n + pad), jnp.float32),
        interpret=interpret,
    )(x, xs, e2)
    return out[:, :n]


@functools.lru_cache(maxsize=None)
def _pallas_fold_fn(mesh, quantile_k, n_bins, block_clients, interpret):
    """The Pallas fold, shard_mapped over the client axis when the ring
    lives on a mesh — each device folds only its own client columns."""
    base = functools.partial(
        _fold_pallas,
        quantile_k=quantile_k,
        n_bins=n_bins,
        block_clients=block_clients,
        interpret=interpret,
    )
    if mesh is None:
        return base
    axis = mesh.axis_names[0]
    return shard_map(
        base,
        mesh=mesh,
        in_specs=(P(None, axis), P(None)),
        out_specs=P(None, axis),
        check_rep=False,  # no replication rule for pallas_call
    )


# --------------------------------------------------------------------- #
# dispatch                                                              #
# --------------------------------------------------------------------- #
@functools.partial(jax.jit, static_argnames=("col", "window"))
def _ring_window(ring, t, hist_len, *, col, window):
    return _window_block(ring, t, hist_len, col, window)


def fold_window(x, spec: SketchSpec, *, backend: str | None = None):
    """Fold a (W, n) time-ordered window matrix (NaN = not observed)
    into `(spec.dim, n)` f32 sketches. Kernel-level entry used by the
    parity tests and benchmarks; the planes go through `sketch_ring`."""
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    x = jnp.asarray(x, jnp.float32)
    edges = jnp.asarray(spec.edges())
    if backend == "xla":
        return _fold_xla(x, edges, quantile_k=spec.quantile_k)
    if backend != "pallas":
        raise ValueError(f"unknown sketch backend {backend!r}")
    return _fold_pallas(
        x,
        edges,
        quantile_k=spec.quantile_k,
        n_bins=spec.bins,
        block_clients=128,
        interpret=not _on_tpu(),
    )


def sketch_ring(
    ring,
    t: int,
    hist_len: int,
    col: int,
    spec: SketchSpec,
    *,
    backend: str | None = None,
    mesh=None,
):
    """Fold column `col`'s last-`spec.window` ring slots into per-client
    sketches, in place where the ring lives. Returns the fused
    `(spec.dim, capacity)` f32 device array — the only thing that
    crosses device→host on the analytics path."""
    if backend is None:
        backend = "pallas" if _on_tpu() else "xla"
    edges = jnp.asarray(spec.edges())
    t = jnp.int32(t)
    hist_len = jnp.int32(hist_len)
    if backend == "xla":
        return _ring_sketch_xla(
            ring, t, hist_len, edges,
            col=col, window=spec.window, quantile_k=spec.quantile_k,
        )
    if backend != "pallas":
        raise ValueError(f"unknown sketch backend {backend!r}")
    x = _ring_window(ring, t, hist_len, col=col, window=spec.window)
    fold = _pallas_fold_fn(mesh, spec.quantile_k, spec.bins, 128, not _on_tpu())
    return fold(x, edges)
