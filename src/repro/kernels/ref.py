"""Pure-jnp oracles for every Pallas kernel (the ground truth the
interpret-mode sweeps in tests/test_kernels.py assert against)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Materializing softmax attention with GQA + causal/window masks."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qg = (q.astype(jnp.float32) * scale).reshape(B, S, KV, G, D)
    s = jnp.einsum("btkgd,bskd->bkgts", qg, k.astype(jnp.float32))
    i = jnp.arange(S)[:, None]
    j = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= i >= j
    if window is not None:
        mask &= (i - j) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgts,bskd->btkgd", p, v.astype(jnp.float32))
    return out.reshape(B, S, H, D).astype(q.dtype)


def ssm_scan_ref(
    dt: jax.Array,  # (B, S, inner) f32
    Bm: jax.Array,  # (B, S, state) f32
    Cm: jax.Array,  # (B, S, state) f32
    x: jax.Array,  # (B, S, inner)
    A: jax.Array,  # (inner, state) f32, negative
    h0: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Sequential selective-scan recurrence (the literal definition)."""
    B, S, inner = dt.shape
    state = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, inner, state), jnp.float32)

    def step(h, t):
        Abar = jnp.exp(dt[:, t][..., None] * A[None])  # (B, inner, state)
        Bx = (dt[:, t] * x[:, t].astype(jnp.float32))[..., None] * Bm[:, t][
            :, None, :
        ]
        h = Abar * h + Bx
        y = jnp.einsum("bis,bs->bi", h, Cm[:, t])
        return h, y

    h, ys = jax.lax.scan(step, h0, jnp.arange(S))
    return ys.transpose(1, 0, 2), h  # (B, S, inner), (B, inner, state)


def quantize_int8_ref(
    x: jax.Array, *, axis: int = -1
) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-row int8 quantization (deterministic round-to-nearest)."""
    absmax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis, keepdims=True)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(
        jnp.int8
    )
    return q, scale.astype(jnp.float32)


def dequantize_int8_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale
