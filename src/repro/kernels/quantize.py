"""Pallas TPU fused int8 quantization kernel (gradient compression).

The AutoSPADA network-budget concern (paper §3.4) turned into a compute
kernel: symmetric per-row absmax int8 quantization, fused scale compute +
cast in one VMEM pass (the XLA path materializes the f32 scaled tensor
before the cast). Used by repro.fleet.compression for result/gradient
uploads on the slow edge.

grid tiles rows; each program reduces its (br, cols) tile to per-row
scales and writes the int8 payload + f32 scales.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (br, cols)
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # (br, 1)
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale[:, 0].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def quantize_int8(
    x: jax.Array,  # (rows, cols)
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    rows, cols = x.shape
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(rows // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, cols), lambda r: (r, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, cols), lambda r: (r, 0)),
            pl.BlockSpec((block_rows,), lambda r: (r,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), jnp.int8),
            jax.ShapeDtypeStruct((rows,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q, s


def dequantize_int8(q: jax.Array, s: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * s[:, None]
