"""jit'd public wrappers over the Pallas kernels with automatic backend
dispatch: TPU -> compiled kernels, anything else -> interpret mode (tests)
or the pure-JAX twins (production CPU paths use repro.models.attention).

Also home to the fleet-scale batched reductions that, like
`repro.fleet.compression.batched_dequant_mean`, collapse a per-client
Python loop into one contraction over the client axis:
`merge_moments` / `merge_histograms` fuse every vehicle's streaming-
analytics sketch into the fleet aggregate in a single jit call.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q
from repro.kernels import ssm_scan as _scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, block_q=128, block_k=256):
    return _fa.flash_attention(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=not _on_tpu(),
    )


def ssm_scan(dt, Bm, Cm, x, A, *, block_inner=512, chunk=128):
    return _scan.ssm_scan(
        dt, Bm, Cm, x, A,
        block_inner=block_inner, chunk=chunk,
        interpret=not _on_tpu(),
    )


def quantize_int8(x, *, block_rows=256):
    return _q.quantize_int8(x, block_rows=block_rows, interpret=not _on_tpu())


dequantize_int8 = _q.dequantize_int8


# --------------------------------------------------------------------- #
# streaming-analytics sketch merges (batched over the client axis)       #
# --------------------------------------------------------------------- #
@jax.jit
def _merge_moments(
    counts: jax.Array,  # (N,) f32 — per-client sample counts
    means: jax.Array,   # (N,) f32 — per-client Welford means
    m2s: jax.Array,     # (N,) f32 — per-client sums of squared deviations
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Chan's parallel moment combination, all clients at once.

    The sequential pairwise merge (`merge_moments_reference` in
    repro.fleet.analytics) telescopes to the closed form
    ``C = Σcᵢ; μ = Σcᵢμᵢ/C; M2 = ΣM2ᵢ + Σcᵢ(μᵢ − μ)²`` — three
    reductions over the client axis instead of an O(N) Python loop,
    mirroring how `batched_dequant_mean` replaced per-client FedAvg."""
    c = jnp.sum(counts)
    safe = jnp.maximum(c, 1.0)
    mean = jnp.sum(counts * means) / safe
    m2 = jnp.sum(m2s) + jnp.sum(counts * jnp.square(means - mean))
    return c, mean, m2


@jax.jit
def _merge_histograms(hists: jax.Array) -> jax.Array:
    """(N, bins) per-client int32 fixed-bin counts -> (bins,) fleet
    counts. Integer accumulation keeps pooled bins exact to 2^31 (f32
    would round past 2^24 — a few hundred thousand vehicles' windows)."""
    return jnp.sum(hists, axis=0, dtype=jnp.int32)


def merge_moments(
    counts: np.ndarray | jax.Array,
    means: np.ndarray | jax.Array,
    m2s: np.ndarray | jax.Array,
) -> tuple[float, float, float]:
    """Merge N clients' (count, mean, M2) sketches in one batched jit
    reduction. Returns (count, mean, M2) of the pooled samples.

    The pooled count is summed exactly in int64 on the host (float32
    cannot represent counts past 2^24 — a few hundred thousand vehicles'
    windows); mean/M2 come from the f32 device reduction, whose relative
    error is ~1e-7 per pooled fleet."""
    c_exact = int(np.sum(np.asarray(counts, np.int64)))
    _, mean, m2 = _merge_moments(
        jnp.asarray(counts, jnp.float32),
        jnp.asarray(means, jnp.float32),
        jnp.asarray(m2s, jnp.float32),
    )
    return float(c_exact), float(mean), float(m2)


def merge_histograms(hists: np.ndarray | jax.Array) -> np.ndarray:
    """Sum N clients' fixed-bin histograms in one batched jit reduction
    (exact integer counts)."""
    out = _merge_histograms(jnp.asarray(hists, jnp.int32))
    return np.asarray(jax.block_until_ready(out)).astype(np.int64)


@jax.jit
def _merge_quantile_sketches(qvals: jax.Array, counts: jax.Array):
    """Weight and co-sort all clients' quantile summaries at once.

    Each of client i's K order statistics stands for count_i / K of its
    samples; NaN entries (count-0 clients, padding) get zero weight so
    they can't shift ranks. argsort puts NaNs last, so the zero-weight
    tail never sits between real values."""
    K = qvals.shape[1]
    w = jnp.broadcast_to((counts / K)[:, None], qvals.shape).reshape(-1)
    v = qvals.reshape(-1)
    w = jnp.where(jnp.isnan(v), 0.0, w)
    order = jnp.argsort(v)
    return v[order], w[order]


def merge_quantile_sketches(
    qvals: np.ndarray | jax.Array,   # (N, K) per-client ranked values
    counts: np.ndarray | jax.Array,  # (N,) per-client sample counts
) -> tuple[np.ndarray, np.ndarray]:
    """Merge N clients' K-point quantile summaries (KLL-style: equal-
    weight order statistics from `compute_sketches` / the payload fold)
    into one fleet summary.

    Returns ``(values, cumulative_weights)`` sorted ascending;
    `WindowStats.quantile` answers queries with one searchsorted.
    Deterministic rank error is at most ``total / (2K)`` plus one sample
    per client (each client's j-th statistic is the midpoint of its j-th
    weight-``count/K`` block). The O(NK log NK) co-sort runs on device;
    the weight cumsum happens in float64 on the host so fleet-scale
    pooled counts don't lose rank precision to f32 accumulation."""
    v, w = _merge_quantile_sketches(
        jnp.asarray(qvals, jnp.float32), jnp.asarray(counts, jnp.float32)
    )
    return np.asarray(v), np.cumsum(np.asarray(w, np.float64))
