"""jit'd public wrappers over the Pallas kernels with automatic backend
dispatch: TPU -> compiled kernels, anything else -> interpret mode (tests)
or the pure-JAX twins (production CPU paths use repro.models.attention)."""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q
from repro.kernels import ssm_scan as _scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal=True, window=None, block_q=128, block_k=256):
    return _fa.flash_attention(
        q, k, v,
        causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        interpret=not _on_tpu(),
    )


def ssm_scan(dt, Bm, Cm, x, A, *, block_inner=512, chunk=128):
    return _scan.ssm_scan(
        dt, Bm, Cm, x, A,
        block_inner=block_inner, chunk=chunk,
        interpret=not _on_tpu(),
    )


def quantize_int8(x, *, block_rows=256):
    return _q.quantize_int8(x, block_rows=block_rows, interpret=not _on_tpu())


dequantize_int8 = _q.dequantize_int8
