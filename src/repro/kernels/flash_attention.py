"""Pallas TPU flash attention (GQA, causal, sliding-window).

TPU-native blocking:
* grid = (B, KV, nq, nk); the first three dims are parallel, the kv dim is
  `arbitrary` (sequential) — running (m, l, acc) state lives in VMEM
  scratch and is carried across kv steps, exactly the online-softmax
  recurrence of repro.models.attention.flash_attention (the XLA twin).
* BlockSpecs tile q/k/v into VMEM: q block (G, bq, D), kv blocks (bk, D) —
  bq/bk default 128/256, multiples of the 8x128 VPU tile and the MXU edge.
* Fully-masked (q_block, kv_block) pairs are skipped with pl.when — on
  causal layouts that's the classic ~2x saving over dense scores; windowed
  layouts skip everything outside the band.
* All softmax math in f32; inputs may be bf16.

head_dim is used as-is (120 for danube lands on padded lanes — wasteful
but correct; noted in DESIGN.md).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, 1, G, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, G, bq, D)
    m_scr,  # (G, bq) f32
    l_scr,  # (G, bq) f32
    acc_scr,  # (G, bq, D) f32
    *,
    scale: float,
    causal: bool,
    window: int | None,
    block_q: int,
    block_k: int,
    n_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    q_start = qi * block_q
    k_start = ki * block_k

    # A (q_block, kv_block) pair is live unless the whole block is masked.
    live = True
    if causal:
        live = jnp.logical_and(live, q_start + block_q - 1 >= k_start)
    if window is not None:
        live = jnp.logical_and(live, q_start - (k_start + block_k - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q,
            k,
            (((2,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (G, bq, bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= q_pos >= k_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
        s = jnp.where(mask[None], s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + jax.lax.dot_general(
            p, v, (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[..., None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0
    nq, nk = S // block_q, S // block_k

    qg = q.reshape(B, S, KV, G, D).transpose(0, 2, 3, 1, 4)  # (B,KV,G,S,D)
    kg = k.transpose(0, 2, 1, 3)  # (B,KV,S,D)
    vg = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel,
        scale=scale,
        causal=causal,
        window=window,
        block_q=block_q,
        block_k=block_k,
        n_k=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, nq, nk),
        in_specs=[
            pl.BlockSpec(
                (1, 1, G, block_q, D), lambda b, h, qi, ki: (b, h, 0, qi, 0)
            ),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, G, block_q, D), lambda b, h, qi, ki: (b, h, 0, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, S, D), q.dtype),
        scratch_shapes=[
            _vmem((G, block_q), jnp.float32),
            _vmem((G, block_q), jnp.float32),
            _vmem((G, block_q, D), jnp.float32),
        ],
        compiler_params=_compiler_params(),
        interpret=interpret,
    )(qg, kg, vg)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, dtype)


def _compiler_params():
    try:
        from jax.experimental.pallas import tpu as pltpu

        return pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")
        )
    except Exception:  # pragma: no cover — older API fallbacks
        return None
