"""Deterministic synthetic data pipeline.

Produces per-host shards of the global batch (tokens/labels or frontend
embeddings per ArchConfig) from a stateless (seed, step) -> batch map, so
any rank can regenerate any step — which is what makes the checkpoint/
restart and elastic re-mesh paths exact: no data-loader state to persist.
A real deployment swaps `synthetic_batch` for a deterministic-sharded
file reader; the (seed, step) contract is the interface.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ArchConfig


def synthetic_batch(
    cfg: ArchConfig,
    *,
    batch: int,
    seq: int,
    seed: int,
    step: int,
    train: bool = True,
) -> dict[str, Any]:
    """Global batch for `step` (identical on every host; slice per host
    with `host_shard`). Markov-chain-ish tokens so the loss is learnable."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    V = cfg.vocab_size
    if cfg.uses_embedding_input:
        out = {
            "frame_embeds": jnp.asarray(
                rng.standard_normal((batch, seq, cfg.d_model)), cfg.dtype
            )
        }
        if train:
            out["labels"] = jnp.asarray(
                rng.integers(0, V, (batch, seq, cfg.n_codebooks)), jnp.int32
            )
        return out
    # learnable structure: tokens follow t[i+1] = (a*t[i]+b) mod V with noise
    a, b = 31, 17
    t0 = rng.integers(0, V, (batch, 1))
    noise = rng.random((batch, seq)) < 0.1
    toks = np.empty((batch, seq), np.int64)
    toks[:, 0] = t0[:, 0]
    for i in range(1, seq):
        toks[:, i] = (a * toks[:, i - 1] + b) % V
    toks = np.where(noise, rng.integers(0, V, (batch, seq)), toks)
    if cfg.frontend == "vit_stub":
        P = cfg.n_patches
        out = {
            "patch_embeds": jnp.asarray(
                rng.standard_normal((batch, P, cfg.d_model)), cfg.dtype
            ),
            "tokens": jnp.asarray(toks[:, : seq - P], jnp.int32),
        }
        if train:
            labels = np.concatenate(
                [np.full((batch, P), -1), toks[:, : seq - P]], axis=1
            )
            out["labels"] = jnp.asarray(labels, jnp.int32)
        return out
    out = {"tokens": jnp.asarray(toks, jnp.int32)}
    if train:
        labels = np.concatenate(
            [toks[:, 1:], np.full((batch, 1), -1)], axis=1
        )
        out["labels"] = jnp.asarray(labels, jnp.int32)
    return out


def host_shard(batch: dict[str, Any], host_index: int, n_hosts: int) -> dict[str, Any]:
    """Slice this host's rows of the global batch."""

    def slc(x):
        per = x.shape[0] // n_hosts
        return x[host_index * per : (host_index + 1) * per]

    return jax.tree.map(slc, batch)
