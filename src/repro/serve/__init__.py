"""Serving: the fleet analyst gateway (`repro.serve.gateway`) and the
LLM continuous-batching engine (`repro.serve.engine`).

Only the gateway is re-exported here — the LLM engine pulls in model
code and is imported explicitly by the paths that serve it.
"""
from repro.serve.gateway import (
    AnalystSession,
    FleetGateway,
    GatewayRequest,
    GatewayResponse,
    Ticket,
)

__all__ = [
    "AnalystSession", "FleetGateway", "GatewayRequest", "GatewayResponse",
    "Ticket",
]
