"""Serving engine: batched prefill + decode over the model substrate.

Request lifecycle mirrors the platform's task lifecycle: requests are
admitted into a fixed-size decode batch (slots), prefilled, decoded until
EOS/max_tokens, then their slot is recycled. On TPU the engine runs under
pjit with the planner's serve shardings; on CPU (examples/tests) it runs
on the host mesh. Greedy or temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ArchConfig, decode_step, prefill


@dataclasses.dataclass
class Request:
    request_id: str
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0
    tokens_out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Static-batch engine (one prefill per batch — the continuous-
    batching slot recycler is layered in serve_loop below)."""

    def __init__(self, cfg: ArchConfig, params: Any, *, cache_len: int):
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self._prefill = jax.jit(
            lambda p, b: prefill(p, cfg, b, cache_len=cache_len)
        )
        self._decode = jax.jit(lambda p, b, c: decode_step(p, cfg, b, c))

    def _sample(self, logits: jax.Array, temperature: float, key) -> jax.Array:
        logits = logits[:, -1]  # (B, V) or (B, K, V)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits / temperature, axis=-1)

    def generate(
        self,
        prompts: np.ndarray,  # (B, S) int32
        *,
        max_new_tokens: int = 16,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> np.ndarray:
        """Greedy/temperature generation for a full batch. (B, new) tokens."""
        B = prompts.shape[0]
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, cache = self._prefill(self.params, batch)
        key = jax.random.PRNGKey(seed)
        outs = []
        tok = self._sample(logits, temperature, key)
        for i in range(max_new_tokens):
            outs.append(np.asarray(tok))
            key, sub = jax.random.split(key)
            logits, cache = self._decode(
                self.params, {"tokens": tok.reshape(B, 1)}, cache
            )
            tok = self._sample(logits, temperature, sub)
        return np.stack(outs, axis=1)  # (B, new)


def serve_loop(
    engine: ServeEngine,
    requests: list[Request],
    *,
    batch_size: int = 4,
    seed: int = 0,
) -> dict[str, list[int]]:
    """Minimal continuous-batching scheduler: admit up to `batch_size`
    requests per wave (padded to a common prompt length), run decode, and
    admit the next wave when slots free up."""
    pending = list(requests)
    results: dict[str, list[int]] = {}
    wave = 0
    while pending:
        batch_reqs = pending[:batch_size]
        pending = pending[batch_size:]
        S = max(r.prompt.shape[0] for r in batch_reqs)
        prompts = np.stack(
            [
                np.pad(r.prompt, (S - r.prompt.shape[0], 0))  # left-pad
                for r in batch_reqs
            ]
        )
        new = engine.generate(
            prompts,
            max_new_tokens=max(r.max_new_tokens for r in batch_reqs),
            temperature=batch_reqs[0].temperature,
            seed=seed + wave,
        )
        for i, r in enumerate(batch_reqs):
            results[r.request_id] = [int(t) for t in new[i, : r.max_new_tokens]]
            r.tokens_out = results[r.request_id]
            r.done = True
        wave += 1
    return results
