"""The fleet query gateway: concurrent analyst sessions over a running
`FleetSimulator` (ROADMAP item 5 — the paper's users are *analysts* who
submit Python tasks to vehicles and read results back from the cloud;
until now the repo only had batch CLI drivers).

Architecture — a deterministic request plane on top of the event engine:

* **Sessions** (`AnalystSession`) submit requests between world ticks.
  Requests land in one FIFO queue; submitting arms a single engine entry
  at the next tick's `PHASE_ADMIT` (before churn, service, and timers),
  so the engine drain itself admits the queue *between ticks*: reads see
  the quiesced end-of-previous-tick snapshot, and submissions commit
  before this tick's churn toggles or service sweep can observe them.
  Admission order is arrival order (one global sequence number), so the
  response stream is a pure function of (seed, request trace) — same
  seed + same trace -> byte-identical `GatewayResponse.encode()` bytes.
  `admit_per_tick` caps admissions per boundary, which turns analyst
  overload into deterministic queueing delay (visible as response ticks
  in `benchmarks/serve_load.py`) instead of tick-time blowup.

* **Read queries** are served at admission, synchronously, against the
  snapshot: fleet gauges (`FleetMetrics.fleet_gauges` — one numpy
  reduction per gauge over the shared columns), platform doc counts
  (`StateStore.doc_counts`, O(1)), per-vehicle signal values/windows
  (plane ring reads), per-assignment round progress (O(1) status-event
  counters), and fleet-level window statistics. The statistics path is
  the load-bearing one: ``fleet_stats``/``quantile`` answers come from
  the plane's *cached per-tick sketch fold* (`fleet_sketch` — ONE fused
  device fold per (tick, signal, spec), shared with every vehicle
  payload and every other analyst that tick), then one
  `WindowStats`-style merge. On the sharded plane the ring never crosses
  device->host for these reads.

* **Submissions** (federated rounds, analytics windows, fused-sketch
  windows) commit a real assignment at admission and arm a
  `DeadlinePump` whose `pump` is a **no-op**: the gateway never advances
  the world from inside a request. Instead `FleetGateway.tick()` runs
  one `FleetSimulator.tick()` and then *settles* — one no-pump
  `DeadlinePump.step()` per in-flight submission, in admission order —
  so quorum/deadline checks happen exactly once per tick boundary and
  many assignments from many analysts progress concurrently over the
  same fleet. When a pump closes, the driver's finish path (aggregate /
  sketch merge) runs and the deferred response completes.

Determinism contract, tested in `tests/test_gateway.py`: reads never
perturb the world (a read-only trace leaves the simulator bit-identical
to an untouched twin), interleaved sessions see the same answers a lone
session would, and full traces replay byte-for-byte.
"""
from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any

import numpy as np

from repro.fleet.analytics import AnalyticsConfig, AnalyticsDriver
from repro.fleet.engine import PHASE_ADMIT
from repro.fleet.federated import FedConfig
from repro.fleet.rounds import FederatedDriver
from repro.kernels.ops import (
    merge_histograms,
    merge_moments,
    merge_quantile_sketches,
)
from repro.kernels.sketch import SketchSpec

if TYPE_CHECKING:  # pragma: no cover
    from repro.fleet.simulator import FleetSimulator

#: request kinds served synchronously at admission
READ_KINDS = (
    "gauges", "platform", "progress", "signal", "window", "fleet_stats",
    "quantile",
)
#: request kinds that commit an assignment and answer when it closes
SUBMIT_KINDS = ("submit_round", "submit_window")


@dataclass(frozen=True)
class GatewayRequest:
    """One analyst request: what was asked, by whom, and when."""

    seq: int
    session: str
    kind: str
    params: dict[str, Any]
    submitted_tick: int


@dataclass(frozen=True)
class GatewayResponse:
    """One served request. ``served_tick - submitted_tick`` is the
    response latency in world ticks (the load benchmark's p50/p99)."""

    seq: int
    session: str
    kind: str
    submitted_tick: int
    served_tick: int
    ok: bool
    body: dict[str, Any]

    @property
    def ticks(self) -> int:
        return self.served_tick - self.submitted_tick

    def to_dict(self) -> dict[str, Any]:
        return {
            "seq": self.seq,
            "session": self.session,
            "kind": self.kind,
            "submitted_tick": self.submitted_tick,
            "served_tick": self.served_tick,
            "ok": self.ok,
            "body": self.body,
        }

    def encode(self) -> bytes:
        """Canonical wire form: sorted keys, no whitespace, shortest
        round-trip floats — the bytes the replay test pins down."""
        return json.dumps(
            self.to_dict(), sort_keys=True, separators=(",", ":")
        ).encode()


class Ticket:
    """Handle a session gets back at submission; `response` fills in when
    the request is served (immediately for reads, at round close for
    submissions)."""

    __slots__ = ("request", "response")

    def __init__(self, request: GatewayRequest):
        self.request = request
        self.response: GatewayResponse | None = None

    @property
    def done(self) -> bool:
        return self.response is not None


class AnalystSession:
    """One analyst's connection: submits requests, collects responses in
    completion order (`inbox`). Thin sugar over `FleetGateway.submit`."""

    def __init__(self, gateway: "FleetGateway", name: str):
        self.gateway = gateway
        self.name = name
        #: responses in completion order (reads at admission, submissions
        #: at round close) — appended by the gateway, drained by the user
        self.inbox: list[GatewayResponse] = []

    def ask(self, kind: str, **params: Any) -> Ticket:
        return self.gateway.submit(self.name, kind, params)

    # -- reads ----------------------------------------------------------- #
    def gauges(self) -> Ticket:
        return self.ask("gauges")

    def platform(self) -> Ticket:
        return self.ask("platform")

    def progress(self, ticket: Ticket | int | None = None) -> Ticket:
        if isinstance(ticket, Ticket):
            ticket = ticket.request.seq
        params = {} if ticket is None else {"ticket": int(ticket)}
        return self.ask("progress", **params)

    def signal(self, client: str | int, signal: str) -> Ticket:
        return self.ask("signal", client=client, signal=signal)

    def window(self, client: str | int, signal: str, k: int) -> Ticket:
        return self.ask("window", client=client, signal=signal, k=int(k))

    def fleet_stats(self, signal: str, **spec: Any) -> Ticket:
        return self.ask("fleet_stats", signal=signal, **spec)

    def quantile(self, signal: str, q: float, **spec: Any) -> Ticket:
        return self.ask("quantile", signal=signal, q=float(q), **spec)

    # -- submissions ------------------------------------------------------ #
    def submit_round(self, **params: Any) -> Ticket:
        return self.ask("submit_round", **params)

    def submit_window(self, signal: str, **params: Any) -> Ticket:
        return self.ask("submit_window", signal=signal, **params)


@dataclass(frozen=True)
class _FleetStats:
    """One merged fleet-level statistics snapshot (tick-cached)."""

    participants: int
    count: int
    mean: float | None
    var: float | None
    hist: tuple[int, ...]
    #: merged quantile summary (values ascending, cumulative weights);
    #: None when no vehicle sketched a sample
    qv: np.ndarray | None
    qw: np.ndarray | None

    def quantile(self, q: float) -> float | None:
        """`WindowStats.quantile` on the merged summary (same formula)."""
        if self.qv is None or self.qv.size == 0:
            return None
        total = float(self.qw[-1])
        if not total > 0:
            return None
        target = min(max(float(q), 0.0), 1.0) * total
        i = int(np.searchsorted(self.qw, target, side="left"))
        i = min(i, len(self.qv) - 1)
        while i > 0 and not np.isfinite(self.qv[i]):
            i -= 1
        return float(self.qv[i])


class _InFlight:
    """A committed submission awaiting its deadline pump's close."""

    __slots__ = ("ticket", "driver", "rif", "finish")

    def __init__(self, ticket: Ticket, driver: Any, rif: Any, finish):
        self.ticket = ticket
        self.driver = driver
        self.rif = rif
        self.finish = finish


def _noop() -> None:
    """The gateway's DeadlinePump `pump`: the world is advanced by
    `FleetGateway.tick`, never from inside a request."""


class FleetGateway:
    """Deterministic analyst gateway over one running `FleetSimulator`.

    Requires the event engine (`Backends(engine="event")`, the default):
    admissions are engine entries and round deadlines are heap timers.
    """

    def __init__(
        self,
        sim: "FleetSimulator",
        *,
        admit_per_tick: int | None = None,
    ):
        if sim.engine is None:
            raise ValueError(
                "FleetGateway needs the unified event engine "
                "(SimConfig backends engine='event'); the dense tick has "
                "no drain to admit requests from"
            )
        if admit_per_tick is not None and admit_per_tick < 1:
            raise ValueError("admit_per_tick must be >= 1")
        self.sim = sim
        self.admit_per_tick = admit_per_tick
        self._sessions: dict[str, AnalystSession] = {}
        self._pending: deque[Ticket] = deque()
        self._inflight: list[_InFlight] = []
        self._by_seq: dict[int, _InFlight] = {}
        self._seq = 0
        self._admit_armed = False
        #: per-session FedAvg drivers: rounds submitted by one analyst
        #: continue that analyst's global model (`driver.w`)
        self._fed: dict[str, FederatedDriver] = {}
        self._fed_next_round: dict[str, int] = {}
        self._window_seq: dict[str, int] = {}
        #: per-tick merged fleet statistics, keyed like the plane's fold
        #: cache: (plane tick, fleet size, signal, spec) — see _fleet_stats
        self._stats_cache: dict = {}
        #: served-request counters by kind (observability, not behavior)
        self.served: dict[str, int] = {}

    # ------------------------------------------------------------------ #
    # the request plane                                                  #
    # ------------------------------------------------------------------ #
    def session(self, name: str) -> AnalystSession:
        s = self._sessions.get(name)
        if s is None:
            s = self._sessions[name] = AnalystSession(self, name)
        return s

    def submit(self, session: str, kind: str, params: dict[str, Any]) -> Ticket:
        """Enqueue one request; it is admitted at the next tick boundary
        (or a later one under `admit_per_tick` backpressure)."""
        self.session(session)  # materialize the inbox
        req = GatewayRequest(
            seq=self._seq,
            session=session,
            kind=kind,
            params=dict(params),
            submitted_tick=self.sim.t,
        )
        self._seq += 1
        ticket = Ticket(req)
        self._pending.append(ticket)
        self._arm()
        return ticket

    def _arm(self) -> None:
        if self._admit_armed or not self._pending:
            return
        eng = self.sim.engine
        # admissions always land at a *future* tick boundary: requests
        # submitted between ticks are admitted when the next drain opens
        eng.schedule(eng.now + 1, self._admit, phase=PHASE_ADMIT, key=0)
        self._admit_armed = True

    def _admit(self) -> None:
        """Engine-drain callback (PHASE_ADMIT): drain the request queue in
        arrival order against the between-ticks snapshot."""
        self._admit_armed = False
        budget = self.admit_per_tick
        n = len(self._pending) if budget is None else min(
            budget, len(self._pending)
        )
        for _ in range(n):
            ticket = self._pending.popleft()
            self._dispatch(ticket)
        self._arm()  # backpressure: anything left waits for the next tick

    def _dispatch(self, ticket: Ticket) -> None:
        req = ticket.request
        try:
            if req.kind in READ_KINDS:
                body = getattr(self, f"_read_{req.kind}")(req.params)
                self._complete(ticket, ok=True, body=body)
            elif req.kind in SUBMIT_KINDS:
                getattr(self, f"_start_{req.kind}")(ticket)
            else:
                raise ValueError(f"unknown request kind {req.kind!r}")
        except (KeyError, ValueError, TypeError) as e:
            # a service answers bad requests, it doesn't crash the world
            self._complete(ticket, ok=False, body={"error": str(e)})

    def _complete(self, ticket: Ticket, *, ok: bool, body: dict) -> None:
        req = ticket.request
        resp = GatewayResponse(
            seq=req.seq,
            session=req.session,
            kind=req.kind,
            submitted_tick=req.submitted_tick,
            served_tick=self.sim.t,
            ok=ok,
            body=body,
        )
        ticket.response = resp
        self._sessions[req.session].inbox.append(resp)
        self.served[req.kind] = self.served.get(req.kind, 0) + 1

    # ------------------------------------------------------------------ #
    # world advancement                                                  #
    # ------------------------------------------------------------------ #
    def tick(self) -> None:
        """One world step: the engine drain admits queued requests at the
        boundary, the simulator ticks, then every in-flight submission
        gets exactly one no-pump quorum/deadline check."""
        self.sim.tick()
        self._settle()

    def _settle(self) -> None:
        if not self._inflight:
            return
        still = []
        for inf in self._inflight:
            if inf.rif.pump.step():  # no-op pump: pure quorum check
                self._by_seq.pop(inf.ticket.request.seq, None)
                inf.finish(inf)
            else:
                still.append(inf)
        self._inflight = still

    @property
    def idle(self) -> bool:
        return not self._pending and not self._inflight

    def run_until_idle(self, max_ticks: int = 100_000) -> int:
        """Tick until every request is served; returns ticks used."""
        used = 0
        while not self.idle:
            if used >= max_ticks:
                raise TimeoutError("gateway did not quiesce")
            self.tick()
            used += 1
        return used

    # ------------------------------------------------------------------ #
    # read handlers (admission-time, snapshot-consistent)                #
    # ------------------------------------------------------------------ #
    def _read_gauges(self, params: dict) -> dict:
        g = self.sim.metrics.fleet_gauges()
        g["tick"] = self.sim.t
        return g

    def _read_platform(self, params: dict) -> dict:
        b = self.sim.broker
        out: dict[str, Any] = dict(self.sim.store.doc_counts())
        out.update(
            published=b.published, delivered=b.delivered, dropped=b.dropped
        )
        return out

    def _read_progress(self, params: dict) -> dict:
        seq = params.get("ticket")
        if seq is None:
            p = self.sim.metrics.progress
            return {"active": 0} if p is None else p.to_dict()
        inf = self._by_seq.get(int(seq))
        if inf is None:
            raise ValueError(f"no in-flight submission with seq {seq}")
        c = inf.rif.assign.counts()
        return {
            "ticket": int(seq),
            "total": inf.rif.n_clients,
            "finished": c.finished,
            "error": c.error,
            "canceled": c.canceled,
            "active": c.active,
        }

    def _plane(self):
        plane = self.sim.plane
        if plane is None:
            raise ValueError("simulator has no signal plane (scripted "
                             "signal_fn worlds serve no signal queries)")
        return plane

    def _row(self, client: str | int) -> int:
        if isinstance(client, str):
            v = self.sim.pool.vehicles.get(client)
            if v is None:
                raise ValueError(f"unknown client {client!r}")
            return int(v.metadata["index"])
        return int(client)

    def _read_signal(self, params: dict) -> dict:
        plane = self._plane()
        val = plane.read(self._row(params["client"]), params["signal"])
        return {"signal": params["signal"], "value": val}

    def _read_window(self, params: dict) -> dict:
        plane = self._plane()
        vals = plane.window(
            self._row(params["client"]), params["signal"], int(params["k"])
        )
        return {"signal": params["signal"], "values": vals}

    def _spec(self, params: dict) -> SketchSpec:
        return SketchSpec(
            window=int(params.get("window", 64)),
            bins=int(params.get("bins", 16)),
            lo=float(params.get("lo", 0.0)),
            hi=float(params.get("hi", 12.0)),
            quantile_k=int(params.get("quantile_k", 32)),
        )

    def _fleet_stats(self, signal: str, spec: SketchSpec) -> "_FleetStats":
        """Fleet-level window statistics out of the cached per-tick fold:
        one `fleet_sketch` hit (shared with vehicle payloads and every
        other analyst this tick) + the batched `WindowStats` merges. The
        merged result is itself cached per tick — under many-analyst
        load, the whole fleet pays ONE ring fold and ONE merge per
        (tick, signal, spec), and every statistics query after the first
        is a dict hit (the guarded ratio in `benchmarks/serve_load.py`).
        The ring never crosses device->host on this path."""
        plane = self._plane()
        key = (plane.t, plane.n_clients, signal, spec)
        st = self._stats_cache.get(key)
        if st is not None:
            return st
        self._stats_cache.clear()
        sk = plane.fleet_sketch(signal, spec)
        counts = sk.counts.astype(np.float32)
        c, mean, m2 = merge_moments(counts, sk.means, sk.m2s)
        hist = merge_histograms(sk.hists)
        qv = qw = None
        if c > 0:
            qv, qw = merge_quantile_sketches(sk.qvals, counts)
        st = _FleetStats(
            participants=int(np.count_nonzero(sk.counts)),
            count=int(c),
            mean=float(mean) if c > 0 else None,
            var=float(m2 / c) if c > 0 else None,
            hist=tuple(int(v) for v in hist),
            qv=qv,
            qw=qw,
        )
        self._stats_cache[key] = st
        return st

    def _read_fleet_stats(self, params: dict) -> dict:
        st = self._fleet_stats(params["signal"], self._spec(params))
        qs = [float(v) for v in params.get("quantiles", (0.5, 0.9))]
        return {
            "signal": params["signal"],
            "participants": st.participants,
            "count": st.count,
            "mean": st.mean,
            "var": st.var,
            "hist": list(st.hist),
            "quantiles": {
                f"p{round(100 * v):02d}": st.quantile(v) for v in qs
            },
        }

    def _read_quantile(self, params: dict) -> dict:
        st = self._fleet_stats(params["signal"], self._spec(params))
        qq = float(params["q"])
        return {
            "signal": params["signal"],
            "q": qq,
            "count": st.count,
            "value": st.quantile(qq),
        }

    # ------------------------------------------------------------------ #
    # submission handlers (deferred responses)                           #
    # ------------------------------------------------------------------ #
    def _start_submit_round(self, ticket: Ticket) -> None:
        req = ticket.request
        p = req.params
        driver = self._fed.get(req.session)
        if driver is None:
            dim = int(p.get("dim", 32))
            w_true = np.sin(np.linspace(0.0, 3.0, dim)).astype(np.float32)
            driver = FederatedDriver(
                self.sim.user,
                FedConfig(
                    local_steps=int(p.get("local_steps", 3)),
                    local_lr=float(p.get("local_lr", 0.2)),
                    deadline_fraction=float(p.get("deadline_fraction", 0.9)),
                    deadline_pumps=int(p.get("deadline_pumps", 64)),
                ),
                dim=dim,
                w_true=w_true,
                n_samples=int(p.get("n_samples", 16)),
                engine=self.sim.engine,
            )
            self._fed[req.session] = driver
            self._fed_next_round[req.session] = 0
        rnd = self._fed_next_round[req.session]
        self._fed_next_round[req.session] = rnd + 1
        rif = driver.start_round(rnd, pump=_noop)
        inf = _InFlight(ticket, driver, rif, self._finish_round)
        self._inflight.append(inf)
        self._by_seq[req.seq] = inf

    def _finish_round(self, inf: _InFlight) -> None:
        rec = inf.driver.finish_round(inf.rif)
        body = {
            "round": rec["round"],
            "participants": rec["participants"],
            "canceled": rec["canceled"],
            "pumps": rec["pumps"],
            "mean_client_loss": rec["mean_client_loss"],
            "dist_to_optimum": rec["dist_to_optimum"],
        }
        self._complete(inf.ticket, ok=True, body=body)

    def _start_submit_window(self, ticket: Ticket) -> None:
        req = ticket.request
        p = req.params
        cfg = AnalyticsConfig(
            signal=p["signal"],
            window=int(p.get("window", 64)),
            bins=int(p.get("bins", 16)),
            lo=float(p.get("lo", 0.0)),
            hi=float(p.get("hi", 12.0)),
            quantile_k=int(p.get("quantile_k", 32)),
            sketch=bool(p.get("sketch", False)),
            deadline_fraction=float(p.get("deadline_fraction", 0.9)),
            deadline_pumps=int(p.get("deadline_pumps", 64)),
        )
        # one driver per submission: windows from different analysts (or
        # different specs) run concurrently without sharing history
        driver = AnalyticsDriver(self.sim.user, cfg, engine=self.sim.engine)
        wid = self._window_seq.get(req.session, 0)
        self._window_seq[req.session] = wid + 1
        wif = driver.start_window(wid, pump=_noop)
        inf = _InFlight(ticket, driver, wif, self._finish_window)
        self._inflight.append(inf)
        self._by_seq[req.seq] = inf

    def _finish_window(self, inf: _InFlight) -> None:
        rec = inf.driver.finish_window(inf.rif)
        body = {
            "window_id": rec.window_id,
            "participants": rec.participants,
            "canceled": rec.canceled,
            "pumps": rec.pumps,
            "count": rec.count,
            "mean": None if np.isnan(rec.mean) else float(rec.mean),
            "var": None if np.isnan(rec.var) else float(rec.var),
            "hist": [int(v) for v in rec.hist],
            "p50": _nan_none(rec.quantile(0.5)),
            "p90": _nan_none(rec.quantile(0.9)),
        }
        self._complete(inf.ticket, ok=True, body=body)


def _nan_none(v: float) -> float | None:
    return None if np.isnan(v) else float(v)
