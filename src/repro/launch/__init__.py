# NOTE: repro.launch.dryrun must be run as a script (it sets XLA_FLAGS);
# do not import it here.
