"""Run the fleet simulator from the command line.

Federated learning (the default workload):

    PYTHONPATH=src python -m repro.launch.fleet --clients 1024 --rounds 5 \
        --drop 0.05 --duplicate 0.02 --delay 2 --stragglers 0.1

Streaming analytics (the paper's data-analytics case study — on-vehicle
Welford/histogram sketches over a drive-cycle signal, merged server-side
in one batched jit reduction per window):

    PYTHONPATH=src python -m repro.launch.fleet --workload analytics \
        --clients 256 --scenario mixed --signal Vehicle.FuelRate --rounds 6

Prints the per-round metrics table and the workload summary. Everything is
a deterministic function of --seed: re-running with identical flags gives
an identical final aggregate (printed as a checksum so drift is visible).
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import numpy as np

from repro.fleet.analytics import AnalyticsConfig, AnalyticsDriver
from repro.fleet.checkpoint import FleetCheckpoint
from repro.fleet.federated import FedConfig
from repro.fleet.metrics import RoundMetrics
from repro.fleet.scenarios import PLANES, SCENARIOS
from repro.fleet.simulator import Backends, FleetSimulator, SimConfig


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--workload", choices=("federated", "analytics"),
                    default="federated")
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--rounds", type=int, default=5,
                    help="FedAvg rounds / analytics windows")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", choices=SCENARIOS, default=None,
                    help="drive-cycle scenario for the signal plane "
                         "(default: road-grade for federated, mixed for "
                         "analytics)")
    ap.add_argument("--plane", choices=PLANES, default="host",
                    help="signal-plane implementation: one columnar host "
                         "array, or rows sharded across devices on a "
                         "`clients` mesh (run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 to "
                         "simulate devices on CPU); bit-for-bit identical")
    ap.add_argument("--dim", type=int, default=32, help="model dimension")
    ap.add_argument("--drop", type=float, default=0.0, help="QoS-0 drop prob")
    ap.add_argument("--duplicate", type=float, default=0.0, help="QoS-1 dup prob")
    ap.add_argument("--delay", type=int, default=0, help="max delivery delay (ticks)")
    ap.add_argument("--leave", type=float, default=0.0, help="per-tick ignition-off prob")
    ap.add_argument("--return", dest="p_return", type=float, default=0.0,
                    help="per-tick ignition-on prob")
    ap.add_argument("--stragglers", type=float, default=0.0,
                    help="fraction of slow clients")
    ap.add_argument("--service", choices=("scheduler", "calendar", "dense"),
                    default="scheduler",
                    help="fleet service: event-driven scheduler "
                         "(O(runnable)/tick), the calendar-queue variant "
                         "(same heap service with periodic refills moved "
                         "into numpy lanes — the 100k+ fast path), or the "
                         "dense poll-loop oracle (O(N)/tick, identical "
                         "interleaving)")
    ap.add_argument("--engine", choices=("event", "dense"), default="event",
                    help="tick orchestration: one unified time-ordered "
                         "event heap (churn toggles, service refills, "
                         "round deadlines — O(events)/tick) or the "
                         "legacy per-subsystem dense tick (the "
                         "bit-for-bit parity oracle)")
    ap.add_argument("--churn", choices=("event", "dense"), default="event",
                    help="churn schedule: seeded geometric event heap or "
                         "the O(N)-scan oracle (identical toggles)")
    ap.add_argument("--deadline", type=float, default=0.9,
                    help="fraction of clients awaited per round")
    ap.add_argument("--deadline-pumps", type=int, default=64,
                    help="hard per-round tick budget")
    # analytics knobs
    ap.add_argument("--signal", default="Vehicle.FuelRate",
                    help="signal the analytics workload sketches")
    ap.add_argument("--window", type=int, default=64,
                    help="on-vehicle samples per sketch")
    ap.add_argument("--bins", type=int, default=16,
                    help="fixed-bin histogram resolution")
    ap.add_argument("--quantile-k", type=int, default=32,
                    help="ranked values per vehicle quantile summary")
    ap.add_argument("--sketch", action="store_true",
                    help="fold windows on device via one fused fleet-wide "
                         "sketch kernel (autospada.get_signal_sketch) "
                         "instead of per-vehicle sandbox loops — same "
                         "result, bit for bit")
    ap.add_argument("--warmup-ticks", type=int, default=16,
                    help="world ticks before the first analytics window")
    # durable fleet state (repro.fleet.checkpoint)
    ap.add_argument("--checkpoint-to", metavar="DIR", default=None,
                    help="directory for durable checkpoints; one "
                         "subdirectory round-NNNN per saved round")
    ap.add_argument("--checkpoint-every", type=int, metavar="N", default=None,
                    help="save a checkpoint after every N completed "
                         "rounds/windows (requires --checkpoint-to)")
    ap.add_argument("--restore-from", metavar="DIR", default=None,
                    help="resume from a checkpoint directory: finishes any "
                         "in-flight round, then runs --rounds more "
                         "(workload/config come from the checkpoint)")
    ap.add_argument("--memory-report", action="store_true",
                    help="print the per-category bytes/client breakdown "
                         "(signal plane, columnar arena, documents, "
                         "queues, client objects) before the workload "
                         "runs")
    return ap


def _checkpoint_hook(ap: argparse.ArgumentParser, args, sim):
    """Returns the on_round/on_window hook saving durable checkpoints
    every N completed rounds, or None when checkpointing is off."""
    if args.checkpoint_every is not None and args.checkpoint_to is None:
        ap.error("--checkpoint-every requires --checkpoint-to")
    if args.checkpoint_to is None:
        return None
    every = args.checkpoint_every if args.checkpoint_every is not None else 1
    if every < 1:
        ap.error("--checkpoint-every must be >= 1")
    root = Path(args.checkpoint_to)
    last: list[Path | None] = [None]

    def hook(rnd: int, driver) -> None:
        if (rnd + 1) % every == 0:
            path = FleetCheckpoint.save(
                sim, root / f"round-{rnd:04d}", driver=driver,
                previous=last[0],  # hardlink unchanged arrays
            )
            last[0] = path
            print(f"checkpoint saved: {path}")

    return hook


def _resume(ap: argparse.ArgumentParser, args) -> None:
    """--restore-from: rebuild the world, finish any in-flight round, run
    --rounds more of whatever workload the checkpoint carries."""
    sim, driver, rif = FleetCheckpoint.restore(args.restore_from)
    if driver is None:
        ap.error(f"checkpoint {args.restore_from} has no workload driver; "
                 "nothing to resume")
    hook = _checkpoint_hook(ap, args, sim)
    if args.memory_report:
        print(FleetSimulator.format_memory_report(sim.memory_report()))
    analytics = isinstance(driver, AnalyticsDriver)
    if rif is not None:
        # finish the round that was mid-flight when the checkpoint was
        # taken, recording its metrics row like the campaign loop does
        online = len(sim.pool.online())
        t0, tick0 = time.perf_counter(), sim.t
        pub0, del0, drop0 = (
            sim.broker.published, sim.broker.delivered, sim.broker.dropped
        )
        if analytics:
            rec = driver.finish_window(rif)
            rnd, participants, canceled = (
                rif.window_id, rec.participants, rec.canceled
            )
            extra = {}
        else:
            rec = driver.finish_round(rif)
            rnd, participants, canceled = (
                rif.rnd, rec["participants"], rec["canceled"]
            )
            extra = {
                "mean_client_loss": rec["mean_client_loss"],
                "dist_to_optimum": rec["dist_to_optimum"],
            }
        sim.metrics.record(
            RoundMetrics(
                round=rnd,
                online_at_start=online,
                participants=participants,
                canceled=canceled,
                ticks=sim.t - tick0,
                published=sim.broker.published - pub0,
                delivered=sim.broker.delivered - del0,
                dropped=sim.broker.dropped - drop0,
                wall_s=time.perf_counter() - t0,
                **extra,
            )
        )
        if hook is not None:
            hook(rnd, driver)
    if analytics:
        driver = sim.run_analytics(
            driver.cfg, windows=args.rounds, driver=driver, on_window=hook
        )
        print(sim.metrics.format_table())
        print(driver.format_table())
    else:
        driver = sim.run_federated(
            driver.cfg, rounds=args.rounds, driver=driver, on_round=hook
        )
        print(sim.metrics.format_table())
        print(f"aggregate checksum: {float(np.sum(driver.w)):.6f}")


def main() -> None:
    ap = build_parser()
    args = ap.parse_args()
    if args.restore_from is not None:
        _resume(ap, args)
        return
    scenario = args.scenario or (
        "mixed" if args.workload == "analytics" else "road-grade"
    )
    sim = FleetSimulator(
        SimConfig(
            n_clients=args.clients,
            seed=args.seed,
            scenario=scenario,
            p_drop=args.drop,
            p_duplicate=args.duplicate,
            max_delay=args.delay,
            p_leave=args.leave,
            p_return=args.p_return,
            straggler_fraction=args.stragglers,
            # CLI strings coerce to the typed enums in Backends
            backends=Backends(
                plane=args.plane,
                service=args.service,
                churn=args.churn,
                engine=args.engine,
            ),
        )
    )
    hook = _checkpoint_hook(ap, args, sim)
    if args.memory_report:
        print(FleetSimulator.format_memory_report(sim.memory_report()))
    if args.workload == "analytics":
        driver = sim.run_analytics(
            AnalyticsConfig(
                signal=args.signal,
                window=args.window,
                bins=args.bins,
                quantile_k=args.quantile_k,
                sketch=args.sketch,
                deadline_fraction=args.deadline,
                deadline_pumps=args.deadline_pumps,
            ),
            windows=args.rounds,
            warmup_ticks=args.warmup_ticks,
            on_window=hook,
        )
        print(sim.metrics.format_table())
        print(driver.format_table())
        if driver.history:
            last = driver.history[-1]
            print(
                f"fleet {args.signal}: mean={last.mean:.4f} std={last.std:.4f} "
                f"p50={last.quantile(0.5):.4f} p90={last.quantile(0.9):.4f} "
                f"over {last.count} on-vehicle samples "
                f"(checksum {last.mean + last.var:.6f})"
            )
        return
    driver = sim.run_federated(
        FedConfig(
            local_steps=3,
            local_lr=0.2,
            deadline_fraction=args.deadline,
            deadline_pumps=args.deadline_pumps,
        ),
        dim=args.dim,
        rounds=args.rounds,
        n_samples=16,
        on_round=hook,
    )
    print(sim.metrics.format_table())
    print(f"aggregate checksum: {float(np.sum(driver.w)):.6f}")


if __name__ == "__main__":
    main()
