"""End-to-end training driver, coordinated by the AutoSPADA control plane.

Every pod-host is a platform *client*; the training job is an
*assignment* whose per-host task exists for the job's lifetime; progress
(steps, losses) and checkpoints flow through the result path with the
paper's cache-until-acknowledged durability. Preemption is survived by
construction: rebuild the host's EdgeClient over the same LocalDisk, ask
the CheckpointManager for the latest acknowledged step, resume.

On real hardware this runs one process per host over the production mesh
(launch with --mesh prod under `jax.distributed`); on CPU it runs the same
code on the host mesh with a reduced config — which is exactly what
examples/train_lm.py demonstrates, including a mid-run simulated
preemption.
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

from repro.configs import get_config, get_tiny
from repro.core import EdgeClient, LocalDisk, User, make_platform
from repro.data.pipeline import synthetic_batch
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import init_params
from repro.train.checkpoint import BlobStore, CheckpointManager
from repro.train.optimizer import OptimizerConfig, init_opt_state
from repro.train.train_step import make_train_step

#: The per-host job task: a long-running (indefinite, paper §4.1.1)
#: payload that heartbeats until canceled; the real work happens in the
#: host process — the task is the job's platform identity (lifecycle,
#: results channel, cancellation point). It must stay ACTIVE for the
#: duration: the server ignores results for non-active tasks.
JOB_PAYLOAD = """
import autospada
autospada.publish({"kind": "job-started"})
while True:
    autospada.sleep(0.05)
"""


class TrainRun:
    """One host's view of a platform-coordinated training job."""

    def __init__(
        self,
        arch: str,
        *,
        tiny: bool = True,
        workdir: str = "experiments/trainrun",
        mesh: str = "host",
        batch: int = 8,
        seq: int = 128,
        seed: int = 0,
        platform=None,  # (store, broker, server) to share across restarts
        disk: LocalDisk | None = None,
        task_id: str | None = None,
    ):
        self.cfg = get_tiny(arch) if tiny else get_config(arch)
        self.batch, self.seq, self.seed = batch, seq, seed
        self.mesh = (
            make_host_mesh() if mesh == "host" else make_production_mesh()
        )
        self.opt_cfg = OptimizerConfig(
            learning_rate=1e-3, warmup_steps=20, moment_dtype="float32"
        )
        self.store, self.broker, self.server = (
            platform if platform else self._fresh_platform()
        )
        self.disk = disk if disk is not None else LocalDisk()
        self.host = EdgeClient(
            "pod-host-0",
            self.server,
            self.broker,
            disk=self.disk,
            thread_containers=True,  # the job heartbeat must not block
        )
        self.host.bootstrap()
        self.host.run_until_idle()
        self.blobs = BlobStore(Path(workdir) / "blobs")
        self.task_id = task_id or self._create_job()
        self.ckpt = CheckpointManager(self.blobs, self.host, self.task_id)
        self._step_fn = None

    def _fresh_platform(self):
        store, broker, (server,) = make_platform()
        return store, broker, server

    def _create_job(self) -> str:
        user = User(self.server, self.broker)
        payload = user.payload(JOB_PAYLOAD, name="train-job")
        assign = user.assignment(
            "train", [user.task("pod-host-0", payload)]
        ).commit()
        self.host.run_until_idle()
        return assign.tasks[0].task_id

    # ------------------------------------------------------------------ #
    def _build_step(self):
        if self._step_fn is None:
            # host mesh: let jit place things
            self._step_fn = jax.jit(make_train_step(self.cfg, self.opt_cfg))
        return self._step_fn

    def init_or_restore(self) -> tuple[dict[str, Any], int]:
        got = self.ckpt.latest(self.server)
        if got is not None:
            step, state = got
            state = jax.tree.map(jax.numpy.asarray, state)
            return state, step
        params = init_params(self.cfg, jax.random.PRNGKey(self.seed))
        return {
            "params": params,
            "opt": init_opt_state(self.opt_cfg, params),
        }, 0

    def run(
        self,
        n_steps: int,
        *,
        ckpt_every: int = 20,
        log_every: int = 10,
        preempt_at: int | None = None,
    ) -> list[dict[str, float]]:
        """Train; optionally raise a simulated preemption at `preempt_at`."""
        step_fn = self._build_step()
        state, start = self.init_or_restore()
        logs = []
        with self.mesh:
            for step in range(start, n_steps):
                if preempt_at is not None and step == preempt_at:
                    raise Preempted(step)
                batch = synthetic_batch(
                    self.cfg,
                    batch=self.batch,
                    seq=self.seq,
                    seed=self.seed,
                    step=step,
                )
                t0 = time.time()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                if (step + 1) % log_every == 0 or step == start:
                    rec = {
                        "step": step + 1,
                        "loss": loss,
                        "sec": time.time() - t0,
                    }
                    logs.append(rec)
                    self.host._on_container_event(
                        self.task_id, result_value={"kind": "metrics", **rec}
                    )
                    self.host.run_until_idle()
                if (step + 1) % ckpt_every == 0 or step + 1 == n_steps:
                    self.ckpt.save(step + 1, jax.tree.map(np.asarray, state))
        return logs


class Preempted(Exception):
    def __init__(self, step: int):
        super().__init__(f"simulated preemption at step {step}")
        self.step = step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-8b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true", help="full (non-tiny) config")
    ap.add_argument("--workdir", default="experiments/trainrun")
    args = ap.parse_args()
    run = TrainRun(
        args.arch,
        tiny=not args.full,
        workdir=args.workdir,
        batch=args.batch,
        seq=args.seq,
    )
    logs = run.run(args.steps)
    for rec in logs:
        print(rec)


if __name__ == "__main__":
    main()
