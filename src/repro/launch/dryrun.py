import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ----------------------------------------------------------------------- #
# Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell and
# extract the roofline terms from the compiled artifact.
#
# The two lines above MUST run before any other import (jax locks the
# device count at first init). Do not move them. Do not import this module
# from tests — run it as a script: PYTHONPATH=src python -m repro.launch.dryrun
# ----------------------------------------------------------------------- #
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.models.model import (  # noqa: E402
    ArchConfig,
    cache_spec,
    decode_step,
    init_params,
    prefill,
)
from repro.sharding import planner  # noqa: E402
from repro.sharding.act import set_batch_axes, set_model_axis  # noqa: E402
from repro.train.optimizer import OptimizerConfig, init_opt_state  # noqa: E402
from repro.train.train_step import make_train_step  # noqa: E402

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}

#: long_500k runs only for sub-quadratic-context archs (DESIGN.md §4).
LONG_OK = {
    "jamba-1.5-large-398b",  # hybrid: SSM state + 9 windowless attn layers
    "gemma3-1b",  # 25/26 layers window-512; O(S) decode on globals
    "h2o-danube-3-4b",  # SWA rolling cache
    "mixtral-8x22b",  # SWA rolling cache (per assignment listing)
    "xlstm-1.3b",  # pure recurrent state
}


def cells(archs=None, shapes=None):
    for a in archs or ARCH_IDS:
        for s in shapes or SHAPES:
            if s == "long_500k" and a not in LONG_OK:
                continue
            yield a, s


# ----------------------------------------------------------------------- #
# input specs (ShapeDtypeStruct stand-ins, never allocated)               #
# ----------------------------------------------------------------------- #
def input_specs(cfg: ArchConfig, shape_name: str) -> dict:
    """ShapeDtypeStructs for every model input of this (arch, shape)."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    i32 = jnp.int32
    f = cfg.dtype
    if sh["kind"] in ("train", "prefill"):
        if cfg.uses_embedding_input:
            batch = {
                "frame_embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), f),
                "labels": jax.ShapeDtypeStruct((B, S, cfg.n_codebooks), i32),
            }
        elif cfg.frontend == "vit_stub":
            P_ = cfg.n_patches
            batch = {
                "patch_embeds": jax.ShapeDtypeStruct((B, P_, cfg.d_model), f),
                "tokens": jax.ShapeDtypeStruct((B, S - P_), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        else:
            batch = {
                "tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
        if sh["kind"] == "prefill":
            batch.pop("labels")
        return {"batch": batch}
    # decode
    if cfg.uses_embedding_input:
        batch = {"frame_embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), f)}
    else:
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    cache = cache_spec(cfg, B, S)
    return {"batch": batch, "cache": cache}


def _opt_cfg(cfg: ArchConfig, n_params_bytes: float) -> OptimizerConfig:
    big = n_params_bytes > 40e9  # >= ~20B params in bf16
    return OptimizerConfig(moment_dtype="bfloat16" if big else "float32")


# ----------------------------------------------------------------------- #
# collective parsing                                                      #
# ----------------------------------------------------------------------- #
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLL_RE = re.compile(
    r"=\s+(\(?[^=]*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, top_n: int = 0):
    """Sum per-device output bytes of collective ops in the *partitioned*
    module (shapes are already local). `-done` ops are skipped (their
    `-start` twin carries the shape). With top_n, also return the largest
    individual ops (the hillclimb profile)."""
    out: dict[str, float] = {}
    tops: list[tuple[float, str]] = []
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        ty, op = m.group(1), m.group(2)
        b = _type_bytes(ty)
        out[op] = out.get(op, 0.0) + b
        if top_n:
            tops.append((b, line.strip()[:240]))
    out["total"] = sum(out.values())
    if top_n:
        tops.sort(key=lambda t: -t[0])
        return out, [{"bytes": b, "op": l} for b, l in tops[:top_n]]
    return out


def sharded_bytes(shapes_tree, shardings_tree, mesh) -> float:
    """Static per-device bytes for a pytree given its shardings."""
    total = 0.0
    for leaf, sh in zip(
        jax.tree.leaves(shapes_tree), jax.tree.leaves(
            shardings_tree, is_leaf=lambda x: hasattr(x, "spec")
        )
    ):
        n = 1
        for axes in sh.spec:
            if axes is None:
                continue
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                n *= mesh.shape[a]
        total += leaf.size * jnp.dtype(leaf.dtype).itemsize / n
    return total


# ----------------------------------------------------------------------- #
# per-cell dry-run                                                        #
# ----------------------------------------------------------------------- #
def run_cell(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    cfg: ArchConfig | None = None,
    opt_cfg: OptimizerConfig | None = None,
    light: bool = False,
    fsdp: bool | None = None,
) -> dict:
    cfg = cfg if cfg is not None else get_config(arch)
    sh = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    # Activation pins (H3.2/H3.3) are needed exactly where propagation
    # can go wrong: FSDP'd weights and MoE dispatch. Small dense train
    # graphs are better left to propagation (measured: pins cost 5-30%
    # there — EXPERIMENTS.md §Perf regressions note).
    param_shapes_probe = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
    )
    pbytes_probe = sum(
        x.size * jnp.dtype(x.dtype).itemsize
        for x in jax.tree.leaves(param_shapes_probe)
    )
    fsdp_like = (fsdp is True) or pbytes_probe > 4e9 * mesh.shape["model"]
    pin = sh["kind"] != "train" or fsdp_like or cfg.moe_experts > 0
    set_batch_axes((("pod", "data") if multi_pod else ("data",)) if pin else None)
    set_model_axis("model", mesh.shape["model"])
    n_dev = mesh.size
    t0 = time.time()

    param_shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    param_bytes_global = sum(
        x.size * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(param_shapes)
    )
    specs = input_specs(cfg, shape_name)
    result: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": sh["kind"],
        "devices": n_dev,
        "param_bytes_global": param_bytes_global,
    }

    if sh["kind"] == "train":
        opt_cfg = opt_cfg or _opt_cfg(cfg, param_bytes_global)
        state_shapes = {
            "params": param_shapes,
            "opt": jax.eval_shape(
                lambda p: init_opt_state(opt_cfg, p), param_shapes
            ),
        }
        param_sh = planner.param_shardings(cfg, param_shapes, mesh, fsdp=fsdp)
        state_sh = {
            "params": param_sh,
            "opt": {
                "m": jax.tree.map(lambda s: s, param_sh),
                "v": jax.tree.map(lambda s: s, param_sh),
                "step": planner.replicated(mesh),
            },
        }
        batch_sh = planner.batch_shardings(specs["batch"], mesh)
        step_fn = make_train_step(cfg, opt_cfg)
        jitted = jax.jit(
            step_fn,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
        )
        with mesh:
            lowered = jitted.lower(state_shapes, specs["batch"])
        result["static_bytes_per_device"] = sharded_bytes(
            jax.tree.leaves(state_shapes), jax.tree.leaves(state_sh), mesh
        )
    elif sh["kind"] == "prefill":
        param_sh = planner.param_shardings(cfg, param_shapes, mesh, serve=True)
        batch_sh = planner.batch_shardings(specs["batch"], mesh)
        fn = lambda p, b: prefill(p, cfg, b, cache_len=sh["seq"])
        jitted = jax.jit(fn, in_shardings=(param_sh, batch_sh))
        with mesh:
            lowered = jitted.lower(param_shapes, specs["batch"])
        result["static_bytes_per_device"] = sharded_bytes(
            param_shapes, param_sh, mesh
        )
    else:  # decode
        param_sh = planner.param_shardings(cfg, param_shapes, mesh, serve=True)
        # wide-serve archs spend the data axis on weight storage; the
        # decode batch is then replicated (activations are B x 1 x d)
        wide = param_bytes_global > 8e9 * mesh.shape["model"]
        batch_sh = planner.batch_shardings(
            specs["batch"], mesh, replicate=wide
        )
        cache_sh = planner.cache_shardings(cfg, specs["cache"], mesh)
        fn = lambda p, b, c: decode_step(p, cfg, b, c)
        jitted = jax.jit(
            fn,
            in_shardings=(param_sh, batch_sh, cache_sh),
            out_shardings=(None, cache_sh),
        )
        with mesh:
            lowered = jitted.lower(param_shapes, specs["batch"], specs["cache"])
        result["static_bytes_per_device"] = sharded_bytes(
            param_shapes, param_sh, mesh
        ) + sharded_bytes(specs["cache"], cache_sh, mesh)

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    # --- analyses ------------------------------------------------------ #
    try:
        if light:
            raise RuntimeError("light probe: skip memory analysis")
        mem = compiled.memory_analysis()
        result["memory_analysis"] = {
            k: getattr(mem, k)
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
    except Exception as e:  # CPU backend may not implement it
        result["memory_analysis"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        result["flops_per_device"] = float(cost.get("flops", -1))
        result["bytes_per_device"] = float(cost.get("bytes accessed", -1))
    except Exception as e:
        result["flops_per_device"] = -1.0
        result["bytes_per_device"] = -1.0
        result["cost_error"] = str(e)

    hlo = compiled.as_text()
    result["collectives"], result["top_collectives"] = collective_bytes(
        hlo, top_n=12
    )
    result["hlo_len"] = len(hlo)

    # --- roofline terms ------------------------------------------------ #
    f = result["flops_per_device"]
    b = result["bytes_per_device"]
    c = result["collectives"]["total"]
    result["roofline"] = {
        "compute_s": f / HW["peak_flops_bf16"] if f > 0 else None,
        "memory_s": b / HW["hbm_bandwidth"] if b > 0 else None,
        "collective_s": c / HW["ici_bandwidth"],
    }
    terms = {
        k: v
        for k, v in zip(
            ("compute", "memory", "collective"),
            (
                result["roofline"]["compute_s"],
                result["roofline"]["memory_s"],
                result["roofline"]["collective_s"],
            ),
        )
        if v is not None
    }
    result["bottleneck"] = max(terms, key=terms.get) if terms else "unknown"
    result["lower_s"] = round(t_lower, 1)
    result["compile_s"] = round(t_compile, 1)
    return result


def _strip_groups(cfg: ArchConfig, keep: int | None) -> ArchConfig:
    """Variant with no layer groups (keep=None) or exactly one pattern
    block of group `keep` (repeats=1) — the probes for scan-aware cost
    accounting (XLA cost_analysis counts while bodies ONCE; see
    EXPERIMENTS.md §Methodology)."""
    import dataclasses

    if keep is None:
        groups = ()
    else:
        pattern, _ = cfg.groups[keep]
        groups = ((pattern, 1),)
    return dataclasses.replace(cfg, groups=groups)


def run_cell_corrected(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    cfg_transform=None,
) -> dict:
    """Full compile (validation + memory) + probe compiles for
    trip-count-corrected FLOPs/bytes/collective accounting.
    cfg_transform(cfg) -> cfg lets the perf hillclimb lower variants."""
    cfg = get_config(arch)
    if cfg_transform is not None:
        cfg = cfg_transform(cfg)
    param_shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
    )
    pbytes = sum(
        x.size * jnp.dtype(x.dtype).itemsize for x in jax.tree.leaves(param_shapes)
    )
    opt_cfg = _opt_cfg(cfg, pbytes)
    fsdp = pbytes > 4e9 * 16  # decided on the FULL model; probes inherit

    full = run_cell(
        arch, shape_name, multi_pod=multi_pod, cfg=cfg, opt_cfg=opt_cfg,
        fsdp=fsdp,
    )
    base = run_cell(
        arch, shape_name, multi_pod=multi_pod,
        cfg=_strip_groups(cfg, None), opt_cfg=opt_cfg, light=True, fsdp=fsdp,
    )

    def get(res):
        return (
            max(res["flops_per_device"], 0.0),
            max(res["bytes_per_device"], 0.0),
            res["collectives"]["total"],
        )

    bf, bb, bc = get(base)
    cf, cb, cc = bf, bb, bc
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        probe = run_cell(
            arch, shape_name, multi_pod=multi_pod,
            cfg=_strip_groups(cfg, gi), opt_cfg=opt_cfg, light=True, fsdp=fsdp,
        )
        pf, pb, pc = get(probe)
        cf += repeats * max(pf - bf, 0.0)
        cb += repeats * max(pb - bb, 0.0)
        cc += repeats * max(pc - bc, 0.0)

    full["corrected"] = {
        "flops_per_device": cf,
        "bytes_per_device": cb,
        "collective_bytes": cc,
        "method": "base+sum(R_g x body_g); probes compiled per group",
    }
    full["roofline_corrected"] = {
        "compute_s": cf / HW["peak_flops_bf16"],
        "memory_s": cb / HW["hbm_bandwidth"],
        "collective_s": cc / HW["ici_bandwidth"],
    }
    terms = full["roofline_corrected"]
    full["bottleneck_corrected"] = max(
        ("compute", "memory", "collective"),
        key=lambda k: terms[f"{k}_s"],
    )
    return full


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch, shape in cells(args.arch, args.shape):
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
            path = outdir / f"{tag}.json"
            if path.exists() and not args.force:
                print(f"[skip] {tag} (cached)")
                continue
            print(f"[run ] {tag} ...", flush=True)
            try:
                t0 = time.time()
                res = run_cell_corrected(arch, shape, multi_pod=mp)
                path.write_text(json.dumps(res, indent=2))
                rt = res["roofline_corrected"]
                print(
                    f"[ ok ] {tag}  {time.time()-t0:6.1f}s  "
                    f"compute={rt['compute_s']:.4g}  memory={rt['memory_s']:.4g}  "
                    f"collective={rt['collective_s']:.4g}  "
                    f"bottleneck={res['bottleneck_corrected']}",
                    flush=True,
                )
            except Exception:
                failures.append(tag)
                err = traceback.format_exc()
                (outdir / f"{tag}.FAILED").write_text(err)
                print(f"[FAIL] {tag}\n{err}", flush=True)

    print(f"\ndone; {len(failures)} failures: {failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
