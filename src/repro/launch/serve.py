"""Serving launcher: front a running fleet with the analyst gateway.

The default mode boots a `FleetSimulator`, opens `--sessions` concurrent
analyst sessions against it through `repro.serve.FleetGateway`, replays a
deterministic request mix (fleet gauges, windowed statistics, quantile
queries, federated rounds, analytics windows), and prints every response
plus the latency summary. Everything is a function of --seed and the
request trace: re-running prints byte-identical response bodies.

    PYTHONPATH=src python -m repro.launch.serve --clients 1024 --sessions 4

`--llm` switches to the original LLM serving path (continuous-batching
`ServeEngine` over a transformer checkpoint):

    PYTHONPATH=src python -m repro.launch.serve --llm --arch qwen3-4b
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--llm", action="store_true",
                    help="serve an LLM (ServeEngine) instead of the fleet")
    # -- fleet gateway mode -------------------------------------------- #
    ap.add_argument("--clients", type=int, default=256)
    ap.add_argument("--sessions", type=int, default=4,
                    help="concurrent analyst sessions")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--scenario", default="mixed")
    ap.add_argument("--plane", default="host",
                    help="signal plane backend: host | sharded")
    ap.add_argument("--signal", default="Vehicle.FuelRate")
    ap.add_argument("--warmup-ticks", type=int, default=16,
                    help="world ticks before the first request")
    ap.add_argument("--rounds", type=int, default=1,
                    help="federated rounds submitted per session")
    ap.add_argument("--windows", type=int, default=1,
                    help="analytics windows submitted per session")
    ap.add_argument("--admit-per-tick", type=int, default=None,
                    help="cap admissions per tick boundary (backpressure)")
    ap.add_argument("--leave", type=float, default=0.0,
                    help="per-tick ignition-off probability")
    ap.add_argument("--return", dest="p_return", type=float, default=0.0,
                    help="per-tick ignition-on probability")
    ap.add_argument("--stragglers", type=float, default=0.0,
                    help="fraction of slow clients")
    # -- LLM mode ------------------------------------------------------ #
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=4)
    return ap


def _fleet_main(args: argparse.Namespace) -> None:
    from repro.fleet.simulator import Backends, FleetSimulator, SimConfig
    from repro.serve.gateway import FleetGateway

    sim = FleetSimulator(
        SimConfig(
            n_clients=args.clients,
            seed=args.seed,
            scenario=args.scenario,
            p_leave=args.leave,
            p_return=args.p_return,
            straggler_fraction=args.stragglers,
            backends=Backends(plane=args.plane),
        )
    )
    for _ in range(args.warmup_ticks):
        sim.tick()
    gw = FleetGateway(sim, admit_per_tick=args.admit_per_tick)

    # the deterministic request mix every session replays: a dashboard
    # poll, fleet-level statistics, a percentile query, then the
    # submissions — all in flight concurrently across sessions
    t0 = time.perf_counter()
    for s in range(args.sessions):
        sess = gw.session(f"analyst-{s}")
        sess.gauges()
        sess.platform()
        sess.fleet_stats(args.signal)
        sess.quantile(args.signal, 0.9)
        for _ in range(args.rounds):
            sess.submit_round()
        for _ in range(args.windows):
            sess.submit_window(args.signal, sketch=True)
    ticks = gw.run_until_idle()
    wall = time.perf_counter() - t0

    responses = [r for s in gw._sessions.values() for r in s.inbox]
    responses.sort(key=lambda r: r.seq)
    for r in responses:
        print(r.encode().decode())
    lat = np.asarray([r.ticks for r in responses], np.float64)
    print(
        f"-- {len(responses)} responses over {ticks} ticks "
        f"({len(responses) / max(wall, 1e-9):.0f} resp/s wall); "
        f"response ticks p50={np.percentile(lat, 50):.0f} "
        f"p99={np.percentile(lat, 99):.0f}"
    )


def _llm_main(args: argparse.Namespace) -> None:
    import jax

    from repro.configs import get_config, get_tiny
    from repro.models import init_params
    from repro.serve.engine import Request, ServeEngine, serve_loop

    cfg = get_config(args.arch) if args.full else get_tiny(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, cache_len=args.cache_len)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            f"req-{i}",
            rng.integers(0, cfg.vocab_size, (int(rng.integers(8, 48)),)),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    results = serve_loop(engine, reqs, batch_size=args.batch_size)
    dt = time.perf_counter() - t0
    tok = sum(len(v) for v in results.values())
    print(f"{len(reqs)} requests -> {tok} tokens in {dt:.2f}s ({tok/dt:.1f} tok/s)")
    for rid in sorted(results):
        print(rid, results[rid])


def main(argv: list[str] | None = None) -> None:
    args = build_parser().parse_args(argv)
    if args.llm:
        _llm_main(args)
    else:
        _fleet_main(args)


if __name__ == "__main__":
    main(sys.argv[1:])
