"""Serving launcher: loads (or inits) a checkpoint and serves batched
requests with the continuous-batching engine.

On real hardware this runs under the production mesh with the planner's
serve shardings (the dry-run proves those compile for every arch); on CPU
it serves the reduced config — same code path.

Run: PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_tiny
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine, serve_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_tiny(args.arch)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, cache_len=args.cache_len)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(
            f"req-{i}",
            rng.integers(0, cfg.vocab_size, (int(rng.integers(8, 48)),)),
            max_new_tokens=args.max_new,
        )
        for i in range(args.requests)
    ]
    t0 = time.perf_counter()
    results = serve_loop(engine, reqs, batch_size=args.batch_size)
    dt = time.perf_counter() - t0
    tok = sum(len(v) for v in results.values())
    print(f"{len(reqs)} requests -> {tok} tokens in {dt:.2f}s ({tok/dt:.1f} tok/s)")
    for rid in sorted(results):
        print(rid, results[rid])


if __name__ == "__main__":
    main()
