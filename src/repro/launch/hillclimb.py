import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512"
    + os.environ.get("EXTRA_XLA_FLAGS", "")
)

# ----------------------------------------------------------------------- #
# Perf hillclimb driver: lower a named variant of a (arch, shape) cell and
# record its corrected roofline terms next to the baseline.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb \
#       --arch granite-8b --shape prefill_32k --variant gqa_repeat
#
# Variants are code-level knobs (ArchConfig fields / planner policy); the
# iteration log lives in EXPERIMENTS.md §Perf.
# ----------------------------------------------------------------------- #
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
from pathlib import Path  # noqa: E402

from repro.launch.dryrun import run_cell_corrected  # noqa: E402

VARIANTS = {
    "baseline": lambda cfg: cfg,
    "gqa_repeat": lambda cfg: dataclasses.replace(cfg, gqa_repeat=True),
    "mamba_chunk64": lambda cfg: dataclasses.replace(cfg, mamba_chunk=64),
    "mamba_chunk256": lambda cfg: dataclasses.replace(cfg, mamba_chunk=256),
    "loss_chunk2k": lambda cfg: dataclasses.replace(cfg, loss_chunk=2048),
    "attn_block_1k": lambda cfg: dataclasses.replace(
        cfg, attn_q_block=1024, attn_kv_block=1024
    ),
    "gqa_repeat+attn1k": lambda cfg: dataclasses.replace(
        cfg, gqa_repeat=True, attn_q_block=1024, attn_kv_block=1024
    ),
    "mamba_bf16": lambda cfg: dataclasses.replace(
        cfg, mamba_scan_dtype="bfloat16"
    ),
    "mamba_bf16+gqa": lambda cfg: dataclasses.replace(
        cfg, mamba_scan_dtype="bfloat16", gqa_repeat=True
    ),
    "mamba_bf16+gqa+chunk256": lambda cfg: dataclasses.replace(
        cfg, mamba_scan_dtype="bfloat16", gqa_repeat=True, mamba_chunk=256
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True, choices=sorted(VARIANTS))
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    res = run_cell_corrected(
        args.arch, args.shape, cfg_transform=VARIANTS[args.variant]
    )
    res["variant"] = args.variant
    tag = f"{args.arch}__{args.shape}__{args.variant}"
    (outdir / f"{tag}.json").write_text(json.dumps(res, indent=2))
    rt = res["roofline_corrected"]
    print(
        f"{tag}: compute={rt['compute_s']:.4g} memory={rt['memory_s']:.4g} "
        f"collective={rt['collective_s']:.4g} "
        f"bottleneck={res['bottleneck_corrected']}"
    )
    for t in res.get("top_collectives", [])[:6]:
        print(f"  {t['bytes']/1e6:10.1f} MB  {t['op'][:150]}")


if __name__ == "__main__":
    main()
