"""Production mesh builders.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first device
query; tests must see the single real CPU device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    The `pod` axis composes with `data` for the batch dimension (pure DP
    across pods: cross-pod traffic is gradient all-reduce only — the right
    default when inter-pod links are DCN-class).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Single-device mesh for CPU smoke tests and examples."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware model used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,  # FLOP/s
    "hbm_bandwidth": 819e9,  # B/s
    "ici_bandwidth": 50e9,  # B/s per link
    "hbm_bytes": 16e9,
}
