"""Result/gradient compression for the slow edge (paper §3.4 network
budget, adapted to distributed learning — see DESIGN.md §2).

* int8 symmetric quantization with per-row scales (Pallas kernel on TPU,
  interpret/jnp elsewhere) — 4x over f32, ~2x over bf16;
* top-k sparsification — transmit the k largest-magnitude entries;
* error feedback (Seide et al. / Karimireddy et al.): the compression
  residual is accumulated locally and added before the next compression,
  which keeps SGD convergent under aggressive compression.

Everything operates on flat f32 vectors; `flatten_pytree`/`unflatten`
adapt parameter pytrees.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.ref import dequantize_int8_ref, quantize_int8_ref


# --------------------------------------------------------------------- #
# pytree <-> flat vector                                                 #
# --------------------------------------------------------------------- #
def flatten_pytree(tree: Any) -> tuple[jax.Array, Any, list]:
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, treedef, shapes


def unflatten_pytree(flat: jax.Array, treedef: Any, shapes: list) -> Any:
    leaves = []
    off = 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        leaves.append(flat[off : off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, leaves)


# --------------------------------------------------------------------- #
# codecs                                                                 #
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class Int8Codec:
    """Per-chunk-of-`row` int8 quantization."""

    row: int = 4096

    def encode(self, flat: jax.Array) -> dict[str, Any]:
        n = flat.shape[0]
        pad = (-n) % self.row
        x = jnp.pad(flat, (0, pad)).reshape(-1, self.row)
        q, s = quantize_int8_ref(x)
        return {"kind": "int8", "q": q, "s": s[:, 0], "n": n}

    def decode(self, msg: dict[str, Any]) -> jax.Array:
        x = dequantize_int8_ref(msg["q"], msg["s"][:, None])
        return x.reshape(-1)[: msg["n"]]

    def nbytes(self, msg: dict[str, Any]) -> int:
        return int(msg["q"].size + msg["s"].size * 4)


@dataclasses.dataclass(frozen=True)
class TopKCodec:
    """Keep the k largest-magnitude entries (indices + values)."""

    fraction: float = 0.01

    def encode(self, flat: jax.Array) -> dict[str, Any]:
        n = flat.shape[0]
        k = max(1, int(n * self.fraction))
        vals, idx = jax.lax.top_k(jnp.abs(flat), k)
        return {
            "kind": "topk",
            "idx": idx.astype(jnp.int32),
            "val": flat[idx],
            "n": n,
        }

    def decode(self, msg: dict[str, Any]) -> jax.Array:
        out = jnp.zeros((msg["n"],), jnp.float32)
        return out.at[msg["idx"]].set(msg["val"])

    def nbytes(self, msg: dict[str, Any]) -> int:
        return int(msg["idx"].size * 4 + msg["val"].size * 4)


@dataclasses.dataclass(frozen=True)
class NullCodec:
    def encode(self, flat: jax.Array) -> dict[str, Any]:
        return {"kind": "raw", "val": flat}

    def decode(self, msg: dict[str, Any]) -> jax.Array:
        return msg["val"]

    def nbytes(self, msg: dict[str, Any]) -> int:
        return int(msg["val"].size * 4)


def make_codec(name: str, **kw) -> Any:
    return {"int8": Int8Codec, "topk": TopKCodec, "none": NullCodec}[name](**kw)


# --------------------------------------------------------------------- #
# batched aggregation (the fleet-scale hot path)                         #
# --------------------------------------------------------------------- #
@jax.jit
def _dequant_weighted_sum(
    q: jax.Array,  # (N, R, row) int8 — all clients' packed deltas, stacked
    s: jax.Array,  # (N, R) f32 per-row scales
    w: jax.Array,  # (N,) f32 normalized aggregation weights
) -> jax.Array:
    """One fused dequantize + weighted-sum over the client axis.

    Algebraically this is `vmap(dequantize_int8_ref)` over clients followed
    by a weighted sum, but folding the aggregation weight into each
    client's dequant scales first (`w_n * s_{nr}`) turns the whole FedAvg
    server step into a single einsum contraction over the client axis —
    XLA fuses the int8->f32 cast straight into the reduction and never
    materializes the (N, R, row) f32 dequantized tensor."""
    ws = w[:, None] * s
    return jnp.einsum("nr,nrc->rc", ws, q.astype(jnp.float32))


@jax.jit
def _dequant_mean_uniform(q: jax.Array, s: jax.Array) -> jax.Array:
    """Unweighted FedAvg mean: the 1/N weight is a compile-time scalar, so
    no weight vector is built or transferred per round."""
    out = jnp.einsum("nr,nrc->rc", s, q.astype(jnp.float32))
    return out / q.shape[0]


def batched_dequant_mean(
    q: np.ndarray | jax.Array,
    s: np.ndarray | jax.Array,
    weights: np.ndarray | None = None,
) -> np.ndarray:
    """Weighted mean of N packed int8 deltas, computed in one batched op.

    `q` is (N, R, row) int8, `s` is (N, R) f32. Returns the (R, row) f32
    mean delta. Replaces the per-client unpack-then-accumulate Python loop
    (see `repro.fleet.rounds.aggregate_reference` for the reference)."""
    if weights is None:
        out = _dequant_mean_uniform(q, s)
    else:
        w = np.asarray(weights, np.float32)
        out = _dequant_weighted_sum(q, s, w / w.sum())
    return np.asarray(jax.block_until_ready(out))


# --------------------------------------------------------------------- #
# error feedback                                                         #
# --------------------------------------------------------------------- #
class ErrorFeedback:
    """Stateful compressor: residual accumulation per client."""

    def __init__(self, codec: Any):
        self.codec = codec
        self._residual: jax.Array | None = None
        self.bytes_sent = 0
        self.bytes_raw = 0

    def compress(self, flat: jax.Array) -> dict[str, Any]:
        if self._residual is not None:
            flat = flat + self._residual
        msg = self.codec.encode(flat)
        decoded = self.codec.decode(msg)
        self._residual = flat - decoded
        self.bytes_sent += self.codec.nbytes(msg)
        self.bytes_raw += int(flat.size * 4)
        return msg

    @property
    def compression_ratio(self) -> float:
        return self.bytes_raw / max(1, self.bytes_sent)
