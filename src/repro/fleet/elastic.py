"""Elastic client pool: vehicles join, drop, and return (paper §2.3 —
no availability assumption is ever made).

The pool owns the simulated fleet: each vehicle is an EdgeClient over its
own LocalDisk (so a returning vehicle resumes with its cached state) plus
a signal source. Signals come in two flavours:

* **plane-backed** (the fleet-scale default): every vehicle's broker is a
  `PlaneSignalView` — a row of one columnar `FleetSignalPlane` advanced by
  a single step per tick (`tick_signals`), not n per-vehicle iterators;
* **scripted** (`signal_fn`): the legacy per-vehicle `ScriptedSignalBroker`
  path, kept for tests and bespoke scripting.

`pump()` advances every *online* vehicle's sync loop; offline vehicles
simply do not run — exactly a vehicle with the ignition off. Deterministic
dropout schedules make the fault-tolerance tests reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.broker import Broker
from repro.core.client import EdgeClient, LocalDisk
from repro.core.columns import FleetColumns
from repro.core.signals import (
    FleetSignalPlane,
    ScriptedSignalBroker,
    SignalBroker,
    constant,
)
from repro.core.statestore import StateStore


@dataclass(slots=True)
class Vehicle:
    client_id: str
    disk: LocalDisk
    signals: SignalBroker
    client: EdgeClient | None = None  # None => powered off
    metadata: dict[str, Any] = field(default_factory=dict)


class FleetPool:
    def __init__(
        self,
        store: StateStore,
        broker: Broker,
        server: Any,
        *,
        n_vehicles: int,
        signal_fn: Callable[[int], dict] | None = None,
        plane: FleetSignalPlane | None = None,
        columns: FleetColumns | None = None,
        seed: int = 0,
    ):
        if signal_fn is not None and plane is not None:
            raise ValueError("pass signal_fn or plane, not both")
        self.store = store
        self.broker = broker
        self.server = server
        self.rng = np.random.default_rng(seed)
        self._signal_fn = signal_fn
        self.plane = plane
        #: shared columnar arena for per-client scalars (clients bind on
        #: power-on; None keeps the legacy per-object scalars)
        self.columns = columns
        # one shared sensors list for plane-backed fleets: every vehicle
        # sees the same signal catalog, so 100k copies is pure overhead
        self._plane_sensors: list[str] | None = None
        #: attached fleet service (repro.fleet.service) notified on power
        #: transitions so wake hooks follow the live EdgeClient instance
        self._service = None
        #: attached churn schedule (repro.fleet.churn) notified on power
        #: transitions so event times always reschedule from the actual
        #: ignition state, even when tests/drivers toggle power directly
        self._churn = None
        self._next_index = 0
        self.vehicles: dict[str, Vehicle] = {}
        if plane is not None and n_vehicles > plane.n_clients:
            # mass admission: reserve plane capacity once up front
            plane.add_clients(n_vehicles - plane.n_clients)
        for _ in range(n_vehicles):
            self.add_vehicle()

    def attach_service(self, service) -> None:
        """Register a fleet service (scheduler or dense oracle) to receive
        power-transition hooks for wake re-wiring."""
        self._service = service

    def attach_churn(self, churn) -> None:
        """Register a churn schedule (repro.fleet.churn) to receive power
        transitions, so geometric event times follow the real ignition
        state."""
        self._churn = churn

    # -- fleet membership ----------------------------------------------- #
    def _make_vehicle(self, i: int) -> Vehicle:
        cid = f"veh-{i:03d}"
        if self.plane is not None:
            while i >= self.plane.n_clients:
                self.plane.add_client()
            signals: SignalBroker = self.plane.view(i)
            if self._plane_sensors is None:
                self._plane_sensors = list(self.plane.names)
            sensors = self._plane_sensors
        else:
            signals = ScriptedSignalBroker(
                self._signal_fn(i)
                if self._signal_fn
                else {"Vehicle.RoadGrade": constant(0.1 * i)}
            )
            sensors = ["Vehicle.RoadGrade"]
        return Vehicle(
            client_id=cid,
            disk=LocalDisk(),
            signals=signals,
            metadata={"sensors": sensors, "index": i},
        )

    def add_vehicle(self) -> str:
        """A brand-new vehicle joins the fleet (paper §2.3: membership is
        elastic in both directions) and powers on immediately."""
        i = self._next_index
        self._next_index += 1
        v = self._make_vehicle(i)
        self.vehicles[v.client_id] = v
        self.power_on(v.client_id)
        return v.client_id

    # -- power control -------------------------------------------------- #
    def power_on(self, cid: str) -> None:
        v = self.vehicles[cid]
        if v.client is not None:
            return
        v.client = EdgeClient(
            cid, self.server, self.broker, disk=v.disk,
            signal_broker=v.signals, metadata=v.metadata,
        )
        if self.columns is not None:
            v.client.bind_columns(self.columns)
        v.client.bootstrap()
        self.store.set_online(cid, True)
        i = v.metadata["index"]
        if self.plane is not None:
            # history-ring masking resumes recording from this tick on
            self.plane.set_online(i, True)
        if self._service is not None:
            self._service.client_powered_on(i, v.client)
        if self._churn is not None:
            self._churn.notify(cid, i, True)

    def power_off(self, cid: str) -> None:
        """Ignition off mid-anything: volatile state is lost, disk survives."""
        v = self.vehicles[cid]
        if v.client is None:
            return
        v.client.shutdown()
        v.client = None
        self.store.set_online(cid, False)
        i = v.metadata["index"]
        if self.plane is not None:
            # plane time keeps running fleet-globally, but nothing is
            # "observed" by a powered-off vehicle: NaN-mask its ring rows
            self.plane.set_online(i, False)
        if self._service is not None:
            self._service.client_powered_off(i)
        if self._churn is not None:
            self._churn.notify(cid, i, False)

    def online(self) -> list[str]:
        return [cid for cid, v in self.vehicles.items() if v.client is not None]

    # -- simulation ------------------------------------------------------#
    def tick_signals(self, *, online_only: bool = False) -> None:
        """Advance the fleet's signals one tick: a single columnar plane
        step when plane-backed (the vectorized hot path; plane time is
        fleet-global, so every row advances), else the legacy per-vehicle
        iterator loop. `online_only` preserves the scripted-path semantics
        of the simulator, where a powered-off vehicle's iterators pause
        until the ignition returns."""
        if self.plane is not None:
            self.plane.step()
            return
        for v in self.vehicles.values():
            if online_only and v.client is None:
                continue
            v.signals.tick()

    def pump(self, dropout_prob: float = 0.0) -> None:
        """One world step: random dropout/return, signal ticks, sync loops."""
        for cid, v in self.vehicles.items():
            if dropout_prob and self.rng.random() < dropout_prob:
                if v.client is None:
                    self.power_on(cid)
                else:
                    self.power_off(cid)
        self.tick_signals()
        for v in self.vehicles.values():
            if v.client is not None:
                v.client.run_until_idle()
