"""Elastic client pool: vehicles join, drop, and return (paper §2.3 —
no availability assumption is ever made).

The pool owns the simulated fleet: each vehicle is an EdgeClient over its
own LocalDisk (so a returning vehicle resumes with its cached state) plus
a scripted signal broker. `pump()` advances every *online* vehicle's sync
loop; offline vehicles simply do not run — exactly a vehicle with the
ignition off. Deterministic dropout schedules make the fault-tolerance
tests reproducible.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import numpy as np

from repro.core.broker import Broker
from repro.core.client import EdgeClient, LocalDisk
from repro.core.signals import ScriptedSignalBroker, constant
from repro.core.statestore import StateStore


@dataclass
class Vehicle:
    client_id: str
    disk: LocalDisk
    signals: ScriptedSignalBroker
    client: EdgeClient | None = None  # None => powered off
    metadata: dict[str, Any] = field(default_factory=dict)


class FleetPool:
    def __init__(
        self,
        store: StateStore,
        broker: Broker,
        server: Any,
        *,
        n_vehicles: int,
        signal_fn: Callable[[int], dict] | None = None,
        seed: int = 0,
    ):
        self.store = store
        self.broker = broker
        self.server = server
        self.rng = np.random.default_rng(seed)
        self._signal_fn = signal_fn
        self._next_index = 0
        self.vehicles: dict[str, Vehicle] = {}
        for _ in range(n_vehicles):
            self.add_vehicle()

    # -- fleet membership ----------------------------------------------- #
    def _make_vehicle(self, i: int) -> Vehicle:
        cid = f"veh-{i:03d}"
        signals = ScriptedSignalBroker(
            self._signal_fn(i)
            if self._signal_fn
            else {"Vehicle.RoadGrade": constant(0.1 * i)}
        )
        return Vehicle(
            client_id=cid,
            disk=LocalDisk(),
            signals=signals,
            metadata={"sensors": ["Vehicle.RoadGrade"], "index": i},
        )

    def add_vehicle(self) -> str:
        """A brand-new vehicle joins the fleet (paper §2.3: membership is
        elastic in both directions) and powers on immediately."""
        i = self._next_index
        self._next_index += 1
        v = self._make_vehicle(i)
        self.vehicles[v.client_id] = v
        self.power_on(v.client_id)
        return v.client_id

    # -- power control -------------------------------------------------- #
    def power_on(self, cid: str) -> None:
        v = self.vehicles[cid]
        if v.client is not None:
            return
        v.client = EdgeClient(
            cid, self.server, self.broker, disk=v.disk,
            signal_broker=v.signals, metadata=v.metadata,
        )
        v.client.bootstrap()
        self.store.set_online(cid, True)

    def power_off(self, cid: str) -> None:
        """Ignition off mid-anything: volatile state is lost, disk survives."""
        v = self.vehicles[cid]
        if v.client is None:
            return
        v.client.shutdown()
        v.client = None
        self.store.set_online(cid, False)

    def online(self) -> list[str]:
        return [cid for cid, v in self.vehicles.items() if v.client is not None]

    # -- simulation ------------------------------------------------------#
    def pump(self, dropout_prob: float = 0.0) -> None:
        """One world step: random dropout/return, signal ticks, sync loops."""
        for cid, v in self.vehicles.items():
            if dropout_prob and self.rng.random() < dropout_prob:
                if v.client is None:
                    self.power_on(cid)
                else:
                    self.power_off(cid)
        for v in self.vehicles.values():
            v.signals.tick()
            if v.client is not None:
                v.client.run_until_idle()
