"""Plane-native fleet service scheduling (ROADMAP: "plane-native client
service").

The simulator's original step-4 loop polled every online `EdgeClient`
every tick: an `idle` check plus an `advance()` per vehicle, each paying
queue/lock overhead even when the client had nothing to do. At N >= 1024
that dense poll is the dominant Python cost of a mostly-idle fleet tick —
the exact central-instance per-client bookkeeping bottleneck OODIDA
(arXiv:1902.00319) reports, and the reason MEDAL (arXiv:2102.13125)
argues for event-driven edge orchestration.

Two interchangeable services implement the same `tick(t)` contract:

* `DensePollService` — the original O(N)-per-tick loop, verbatim. Kept as
  the **parity oracle**: the scheduler must reproduce its event
  interleaving bit-for-bit (same broker message ids => same seeded fault
  schedule => same aggregate), and `tests/test_service.py` proves it.
* `FleetServiceScheduler` — event-driven: clients become *runnable* via
  cheap wake hooks (broker delivery to their clock topic, container-event
  enqueue, `EdgeClient._spawn`) instead of being polled. Straggler and
  resync phase gating is evaluated as vectorized numpy masks over the
  whole fleet, so one tick costs a couple of numpy ops plus a Python loop
  over only the runnable/resync-due clients — O(runnable), not O(N).

Parity argument (why skipping idle clients is bit-for-bit safe): a dense
iteration over an idle, non-resync-due client performs no broker-visible
action (`advance` finds no events and no ops), so eliding it cannot
perturb the publish order, the message-id sequence, or any client state.
Clients woken *during* a sweep by an earlier-indexed client's service are
picked up at their index position exactly as the dense loop would reach
them; wakes at already-passed indices stay runnable for the next tick,
which is also what the dense loop does.
"""
from __future__ import annotations

import heapq
import threading
from typing import TYPE_CHECKING, Iterable

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.client import EdgeClient
    from repro.fleet.elastic import FleetPool


class DensePollService:
    """The original per-tick poll loop over every vehicle — the parity
    oracle and benchmark baseline for `FleetServiceScheduler`."""

    def __init__(
        self,
        pool: "FleetPool",
        *,
        steps_per_tick: int,
        resync_period: int,
        straggler_period: int,
        stragglers: Iterable[str] = (),
    ):
        self.pool = pool
        self.steps_per_tick = steps_per_tick
        self.resync_period = resync_period
        self.straggler_period = straggler_period
        self.stragglers = set(stragglers)
        #: clients actually advanced last tick (dense: every online,
        #: non-gated vehicle, idle or not)
        self.last_serviced = 0

    def tick(self, t: int) -> None:
        served = 0
        for i, (cid, v) in enumerate(self.pool.vehicles.items()):
            c = v.client
            if c is None:
                continue
            if cid in self.stragglers and (t + i) % self.straggler_period:
                continue  # straggler: skips this tick's service slot
            if c.idle and (t + i) % self.resync_period == 0:
                # periodic dial-in recovers dropped QoS-0 notifications
                c.resync()
            c.advance(self.steps_per_tick)
            served += 1
        self.last_serviced = served

    # pool membership hooks (the dense loop re-scans the pool every tick,
    # so it needs none of this)
    def client_powered_on(self, index: int, client: "EdgeClient") -> None:
        pass

    def client_powered_off(self, index: int) -> None:
        pass


class FleetServiceScheduler:
    """Event-driven runnable set + vectorized phase gating.

    State is indexed by vehicle index (`Vehicle.metadata["index"]`, which
    equals the vehicle's position in `pool.vehicles` — entries are only
    ever appended):

    * ``_online`` / ``_runnable`` / ``_straggler`` — numpy bool arrays;
    * ``_clients`` — index -> live `EdgeClient` (None while powered off).

    A client's wake hook sets its runnable bit (and, mid-sweep, enqueues
    it into the current tick's heap if its index has not been passed yet).
    Each `tick` computes the straggler/resync phase masks for the whole
    fleet in a few vectorized numpy expressions and then services only the
    candidate indices, in ascending order — the dense loop's order.
    """

    #: The mask-based tick() gates the fleet with per-index numpy arrays
    #: (`_idx`, `_online`). `EngineService` replaces tick() with heap-fed
    #: events and never reads them, so it opts out and the event path
    #: carries no dead per-tick gating state (the dense oracle keeps its
    #: own copy).
    _uses_masks = True

    def __init__(
        self,
        pool: "FleetPool",
        *,
        steps_per_tick: int,
        resync_period: int,
        straggler_period: int,
        straggler_indices: Iterable[int] = (),
    ):
        self.pool = pool
        self.steps_per_tick = steps_per_tick
        self.resync_period = resync_period
        self.straggler_period = straggler_period
        n = max(1, len(pool.vehicles))
        self._capacity = n
        if self._uses_masks:
            self._idx = np.arange(n)
            self._online = np.zeros(n, bool)
        # gating state lives in the pool's shared FleetColumns arena when
        # one is attached (the columnar control plane: StateStore, the
        # services, and FleetMetrics all view the same per-client arrays);
        # detached pools fall back to private arrays. Access goes through
        # the `_runnable`/`_straggler` properties — the arena reallocates
        # on growth, so views are taken at use time, never cached.
        self._cols = getattr(pool, "columns", None)
        if self._cols is not None:
            self._cols.ensure(n)
            self._cols.runnable[:n] = False
            self._cols.straggler[:n] = False
        else:
            self._runnable_local = np.zeros(n, bool)
            self._straggler_local = np.zeros(n, bool)
        self._clients: list["EdgeClient | None"] = [None] * n
        for i in straggler_indices:
            self._ensure_index(i)
            self._straggler[i] = True
        # sweep state: a heap of indices still to service this tick (None
        # outside `tick`), the highest index already serviced, and the
        # thread running the sweep (only same-thread wakes may touch the
        # heap)
        self._live: list[int] | None = None
        self._cursor = -1
        self._sweep_thread: threading.Thread | None = None
        self.last_serviced = 0
        for v in pool.vehicles.values():
            if v.client is not None:
                self.client_powered_on(v.metadata["index"], v.client)

    # ------------------------------------------------------------------ #
    # gating columns (arena-backed when the pool carries a FleetColumns) #
    # ------------------------------------------------------------------ #
    @property
    def _runnable(self) -> np.ndarray:
        if self._cols is not None:
            return self._cols.runnable[: self._capacity]
        return self._runnable_local

    @_runnable.setter
    def _runnable(self, arr) -> None:
        if self._cols is not None:
            self._cols.runnable[: self._capacity] = arr
        else:
            self._runnable_local = np.asarray(arr, bool)

    @property
    def _straggler(self) -> np.ndarray:
        if self._cols is not None:
            return self._cols.straggler[: self._capacity]
        return self._straggler_local

    @_straggler.setter
    def _straggler(self, arr) -> None:
        if self._cols is not None:
            self._cols.straggler[: self._capacity] = arr
        else:
            self._straggler_local = np.asarray(arr, bool)

    # ------------------------------------------------------------------ #
    # wake plumbing                                                      #
    # ------------------------------------------------------------------ #
    def _make_wake(self, i: int):
        def wake() -> None:
            live = self._live
            if (
                live is not None
                and threading.current_thread() is self._sweep_thread
            ):
                if i == self._cursor:
                    # the client being serviced woke itself (an op spawned
                    # and consumed within its own advance): the sweep's
                    # post-advance has_work check decides runnability, so
                    # setting the bit here would leave it stale
                    return
                self._runnable[i] = True
                if i > self._cursor:
                    # woken mid-sweep at an index the dense loop has not
                    # reached yet: service it this tick, in order
                    heapq.heappush(live, i)
                return
            # outside a sweep, or from another thread (a ContainerThread's
            # exit callback): only set the bit — heapq on a plain list is
            # not thread-safe, and the next tick picks the bit up anyway
            self._runnable[i] = True

        return wake

    def _ensure_index(self, i: int) -> None:
        if i < self._capacity:
            return
        cap = max(i + 1, 2 * self._capacity)
        if self._uses_masks:
            self._idx = np.arange(cap)
            arr = np.zeros(cap, bool)
            arr[: self._capacity] = self._online
            self._online = arr
        if self._cols is not None:
            self._cols.ensure(cap)  # new rows default runnable/straggler=False
        else:
            for name in ("_runnable_local", "_straggler_local"):
                arr = np.zeros(cap, bool)
                arr[: self._capacity] = getattr(self, name)
                setattr(self, name, arr)
        self._clients.extend([None] * (cap - self._capacity))
        self._capacity = cap

    # pool membership hooks ------------------------------------------------
    def client_powered_on(self, index: int, client: "EdgeClient") -> None:
        self._ensure_index(index)
        self._clients[index] = client
        if self._uses_masks:
            self._online[index] = True
        client.set_wake(self._make_wake(index))
        # bootstrap already spawned ops before the hook ran: seed from the
        # client's actual state rather than assuming idle
        self._runnable[index] = client.has_work
        if (
            self._live is not None
            and self._runnable[index]
            and index > self._cursor
        ):
            heapq.heappush(self._live, index)

    def client_powered_off(self, index: int) -> None:
        if index >= self._capacity:
            return
        c = self._clients[index]
        if c is not None:
            c.set_wake(None)
        self._clients[index] = None
        if self._uses_masks:
            self._online[index] = False
        self._runnable[index] = False

    # ------------------------------------------------------------------ #
    # the per-tick sweep                                                 #
    # ------------------------------------------------------------------ #
    def tick(self, t: int) -> None:
        idx = self._idx
        # vectorized phase gating over the whole fleet: two numpy masks
        # replace N per-client modulo checks
        phase = (t + idx) % self.resync_period == 0
        gated = self._straggler & (((t + idx) % self.straggler_period) != 0)
        cand = self._online & ~gated & (self._runnable | phase)
        live = [int(i) for i in np.flatnonzero(cand)]  # ascending => a heap
        self._sweep(live, t)

    # hooks the engine-native subclass overrides (repro.fleet.engine):
    # the sweep below is the parity-critical loop both services share
    def _on_gated_skip(self, i: int, t: int) -> None:
        """A gated straggler surfaced mid-sweep; the mask recomputation
        next tick re-examines it, so the base scheduler needs no note."""

    def _note_runnable(self, i: int) -> None:
        """Post-advance re-arm: the client still has work."""
        self._runnable[i] = True

    def _sweep(self, live: list[int], t: int) -> None:
        """Service `live` (a heap of candidate indices) in ascending
        order — the dense loop's order. Shared verbatim by the scheduler
        and `EngineService`, so the bit-for-bit parity argument holds for
        both: gating, the clear-then-set runnable discipline, and the
        post-advance re-arm are identical."""
        self._live = live
        self._cursor = -1
        self._sweep_thread = threading.current_thread()
        served = 0
        try:
            while live:
                i = heapq.heappop(live)
                if i <= self._cursor:
                    continue  # duplicate wake for an already-serviced index
                self._cursor = i
                c = self._clients[i]
                if c is None:
                    continue
                if self._straggler[i] and (t + i) % self.straggler_period:
                    self._on_gated_skip(i, t)
                    continue  # gated straggler woken mid-sweep: next slot
                # clear-then-set, never assign after advance: a cross-thread
                # wake landing between `c.has_work` and the store must not
                # be clobbered ("missed wakes are not [allowed]")
                self._runnable[i] = False
                if not c.has_work and (t + i) % self.resync_period == 0:
                    c.resync()
                c.advance(self.steps_per_tick)
                if c.has_work:
                    self._note_runnable(i)
                served += 1
        finally:
            self._live = None
            self._cursor = -1
            self._sweep_thread = None
        self.last_serviced = served


def make_service(
    kind: str,
    pool: "FleetPool",
    *,
    steps_per_tick: int,
    resync_period: int,
    straggler_period: int,
    straggler_indices: Iterable[int] = (),
):
    """Build the configured service implementation ("scheduler" is the
    event-driven default; "dense" is the poll-loop parity oracle).

    Both take the straggler set as vehicle *indices* — the dense oracle's
    cid set is derived here, so the two representations cannot drift and
    silently break the bit-for-bit parity contract."""
    if kind == "dense":
        idx = set(straggler_indices)
        return DensePollService(
            pool,
            steps_per_tick=steps_per_tick,
            resync_period=resync_period,
            straggler_period=straggler_period,
            stragglers={
                cid
                for cid, v in pool.vehicles.items()
                if v.metadata["index"] in idx
            },
        )
    if kind == "scheduler":
        return FleetServiceScheduler(
            pool,
            steps_per_tick=steps_per_tick,
            resync_period=resync_period,
            straggler_period=straggler_period,
            straggler_indices=straggler_indices,
        )
    raise ValueError(f"unknown service kind {kind!r}; use 'scheduler' or 'dense'")
