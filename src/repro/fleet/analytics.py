"""Streaming data analytics over the fleet — the paper's second workload.

AutoSPADA's operational case study is not learning: it is *streaming
statistics over fuel-consumption signals* computed on-vehicle with only
compact summaries leaving the car (OODIDA's on-board/off-board analytics
split). This module is that workload on our platform:

1. an `AnalyticsDriver` window is one assignment to every online vehicle;
2. each vehicle's task container reads the last `window` observations of a
   signal from its signal plane view (`autospada.get_signal_window`),
   folds them through Welford's online mean/variance and a fixed-bin
   histogram, and publishes the resulting *sketch* — (count, mean, M2,
   bin counts), O(bins) bytes no matter how many samples were seen;
3. the server stacks all vehicles' sketches and merges them in one
   batched jit reduction (`repro.kernels.ops.merge_moments` /
   `merge_histograms` — the analytics twin of `batched_dequant_mean`),
   yielding exact fleet-level mean/variance/histogram as if every raw
   sample had been uploaded.

`merge_moments_reference` is the sequential pairwise (Chan et al.) merge,
kept as the oracle the batched path is tested against.

Two payload paths produce the *same sketch, bit for bit*:

* `ANALYTICS_PAYLOAD` (the oracle) folds `get_signal_window` in a
  sandboxed numpy loop — float32 Welford with the deferred-product
  update, >=-edge histogram binning, integer-rank quantile selection
  (`kernels.sketch.sketch_reference` is the same formula as a
  function);
* `SKETCH_PAYLOAD` (``AnalyticsConfig(sketch=True)``) calls
  `autospada.get_signal_sketch`, which on plane-attached vehicles is
  answered by ONE fused fleet-wide device fold over the signal ring
  per tick (`compute_sketches`, cached) — N sandboxed Python folds and
  the device→host ring sync collapse into a single kernel call.

Sketches now carry a mergeable KLL-style quantile summary (`qsk`), so
`WindowStats.quantile(q)` answers fleet-level percentile queries with a
deterministic rank-error bound — the paper's fuel-consumption analytics
("what's the 90th-percentile fuel rate across the fleet?") without any
raw sample leaving a vehicle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.user import AssignmentDoc, User
from repro.fleet.rounds import DeadlinePump
from repro.kernels.ops import (
    merge_histograms,
    merge_moments,
    merge_quantile_sketches,
)

#: Payload template executed inside every vehicle's task container: fold a
#: signal window through Welford + fixed bins + ranked quantile values,
#: publish the sketch only. This is the per-vehicle ORACLE the fused
#: device path (`SKETCH_PAYLOAD` → `compute_sketches`) must match bit for
#: bit, so every operation is pinned to float32 semantics the kernels can
#: reproduce exactly: the Welford mean/M2 updates run on np.float32
#: scalars, binning compares against precomputed f32 interior edges
#: (comparisons are exact where the old width-division was not), and the
#: quantile summary selects K order statistics at integer ranks of the
#: f32-sorted window — the same formula as
#: `kernels.sketch.sketch_reference`.
ANALYTICS_PAYLOAD = """
import autospada
import numpy as np

p = autospada.get_parameters()
sig = p["signal"]
xs = autospada.get_signal_window(sig, int(p["window"]))
x = np.asarray(xs, dtype=np.float32)
count = int(x.shape[0])
c = np.float32(0.0)
one = np.float32(1.0)
mean = np.float32(0.0)
m2 = np.float32(0.0)
for v in x:
    c = c + one
    d = v - mean
    mean = mean + d / c
    m2 = m2 + d * (v - mean)
nb = int(p["bins"])
lo = float(p["lo"])
hi = float(p["hi"])
K = int(p["quantile_k"])
width = (hi - lo) / nb
edges = (lo + width * np.arange(1, nb)).astype(np.float32)
if count:
    idx = (x[:, None] >= edges[None, :]).sum(axis=1)
    hist = np.bincount(idx, minlength=nb)
    xs_sorted = np.sort(x)
    ranks = np.minimum((2 * np.arange(K) + 1) * count // (2 * K), count - 1)
    qsk = [float(v) for v in xs_sorted[ranks]]
else:
    hist = np.zeros((nb,), np.int64)
    qsk = []
autospada.publish({
    "window_id": int(p["window_id"]),
    "signal": sig,
    "count": int(count),
    "mean": float(mean),
    "m2": float(m2),
    "hist": [int(v) for v in hist],
    "qsk": qsk,
})
"""

#: The vectorized sibling: one `autospada.get_signal_sketch` call. On
#: plane-attached vehicles the answer comes from the fleet-wide cached
#: device fold (`FleetSignalPlane.sketch_row`) — the window never crosses
#: into the sandbox and the ring never crosses to the host — and on any
#: other source from the identical reference formula, so both payloads
#: publish the same values bit for bit.
SKETCH_PAYLOAD = """
import autospada

p = autospada.get_parameters()
sk = autospada.get_signal_sketch(
    p["signal"],
    int(p["window"]),
    bins=int(p["bins"]),
    lo=float(p["lo"]),
    hi=float(p["hi"]),
    quantile_k=int(p["quantile_k"]),
)
sk["window_id"] = int(p["window_id"])
sk["signal"] = p["signal"]
autospada.publish(sk)
"""


@dataclasses.dataclass(frozen=True)
class AnalyticsConfig:
    """One streaming-statistics campaign over a vehicle signal."""

    signal: str = "Vehicle.FuelRate"
    window: int = 64        # on-vehicle samples folded per sketch
    bins: int = 16          # fixed-bin histogram resolution
    lo: float = 0.0         # histogram support (clipped at the edges);
    hi: float = 12.0        # default spans the drive-cycle fuel-rate range
    quantile_k: int = 32    # ranked values per vehicle quantile summary
    sketch: bool = False    # True: fused device sketches (SKETCH_PAYLOAD)
    deadline_fraction: float = 0.9
    deadline_pumps: int | None = 64


@dataclasses.dataclass(frozen=True)
class WindowStats:
    """Fleet-level statistics of one analytics window."""

    window_id: int
    participants: int
    canceled: int
    pumps: int
    count: int          # pooled on-vehicle samples behind this window
    mean: float
    var: float          # population variance of the pooled samples
    hist: np.ndarray    # (bins,) pooled fixed-bin counts
    #: merged quantile summary: values sorted ascending with cumulative
    #: weights (sample mass at-or-below each value); None for legacy
    #: sketches without a `qsk` field
    q_values: np.ndarray | None = None
    q_weights: np.ndarray | None = None

    @property
    def std(self) -> float:
        return float(np.sqrt(max(self.var, 0.0)))

    def quantile(self, q: float) -> float:
        """Fleet-level q-quantile estimate from the merged per-vehicle
        summaries: one searchsorted over the cumulative weights.
        Deterministic rank error is bounded by ``count / (2 *
        quantile_k)`` plus one sample per participant — no raw sample
        ever left a vehicle to earn it. NaN when no samples merged."""
        if self.q_values is None or self.q_values.size == 0:
            return float("nan")
        total = float(self.q_weights[-1])
        if not total > 0:
            return float("nan")
        target = min(max(float(q), 0.0), 1.0) * total
        i = int(np.searchsorted(self.q_weights, target, side="left"))
        i = min(i, len(self.q_values) - 1)
        # zero-weight NaN entries (count-0 vehicles) sort to the tail;
        # a q=1.0 query must step back onto the last real value
        while i > 0 and not np.isfinite(self.q_values[i]):
            i -= 1
        return float(self.q_values[i])


def merge_moments_reference(
    sketches: Iterable[tuple[float, float, float]]
) -> tuple[float, float, float]:
    """Sequential pairwise Chan merge of (count, mean, M2) sketches — the
    per-client loop the batched `kernels.ops.merge_moments` replaces, kept
    as the correctness oracle."""
    c, mean, m2 = 0.0, 0.0, 0.0
    for ci, mi, m2i in sketches:
        ci = float(ci)
        if ci <= 0:
            continue
        tot = c + ci
        delta = mi - mean
        mean += delta * ci / tot
        m2 += m2i + delta * delta * c * ci / tot
        c = tot
    return c, mean, m2


@dataclasses.dataclass
class WindowInFlight:
    """A committed-but-not-closed analytics window: the assignment plus
    its armed `DeadlinePump` (the analytics twin of
    `repro.fleet.rounds.RoundInFlight`)."""

    window_id: int
    n_clients: int
    assign: AssignmentDoc
    pump: DeadlinePump


class AnalyticsDriver:
    """Runs windowed streaming-statistics assignments through the platform
    (the analytics sibling of `FederatedDriver`)."""

    def __init__(
        self,
        user: User,
        cfg: AnalyticsConfig,
        *,
        engine: Any = None,
        status_oracle: bool = False,
        metrics: Any = None,
    ):
        self.user = user
        self.cfg = cfg
        #: unified event engine: window deadlines become heap entries; the
        #: quorum check reads AssignmentDoc.counts() (status events), with
        #: status_oracle=True restoring the dense statuses() scan
        self.engine = engine
        self.status_oracle = status_oracle
        #: FleetMetrics sink for live per-window progress gauges (fed from
        #: the same status-event counters the deadline check reads)
        self.metrics = metrics
        self.history: list[WindowStats] = []
        #: raw per-vehicle sketches of the most recent window (tests replay
        #: the batched merge against the sequential reference with these)
        self.last_sketches: list[dict[str, Any]] = []

    def start_window(
        self, window_id: int, pump: Callable[[], None]
    ) -> "WindowInFlight":
        """Commit one window's assignment and arm its deadline pump
        without pumping — the suspension point `repro.fleet.checkpoint`
        uses to snapshot a window mid-flight."""
        cfg = self.cfg
        clients = self.user.online_clients()
        source = SKETCH_PAYLOAD if cfg.sketch else ANALYTICS_PAYLOAD
        payload = self.user.payload(source, name=f"analytics-w{window_id}")
        # one immutable Parameters doc shared by every task — the sketch
        # spec is fleet-wide, unlike FedAvg's per-client data seeds
        params = self.user.parameter(
            {
                "signal": cfg.signal,
                "window": cfg.window,
                "bins": cfg.bins,
                "lo": cfg.lo,
                "hi": cfg.hi,
                "quantile_k": cfg.quantile_k,
                "window_id": window_id,
            }
        )
        tasks = [self.user.task(c, payload, params) for c in clients]
        assign = self.user.assignment(
            f"analytics window {window_id}", tasks
        ).commit()
        need = max(1, int(len(clients) * cfg.deadline_fraction))
        on_counts = None
        if self.metrics is not None:
            self.metrics.begin_round(window_id, len(clients))
            on_counts = self.metrics.update_progress
        dpump = DeadlinePump(
            assign,
            len(clients),
            need=need,
            budget=cfg.deadline_pumps,
            pump=pump,
            engine=self.engine,
            status_oracle=self.status_oracle,
            on_counts=on_counts,
        )
        return WindowInFlight(
            window_id=window_id,
            n_clients=len(clients),
            assign=assign,
            pump=dpump,
        )

    def finish_window(self, wif: "WindowInFlight") -> WindowStats:
        """Pump an in-flight window to its close and merge the sketches."""
        window_id = wif.window_id
        assign = wif.assign
        pumps = wif.pump.run()
        canceled = assign.cancel()
        if self.metrics is not None:
            # final gauge including the deadline cancels (cancel() above
            # published CANCELED statuses into the same counters)
            self.metrics.update_progress(assign.counts())
        sketches = []
        for values in assign.results().values():
            for v in values:
                if (
                    isinstance(v, dict)
                    and v.get("window_id") == window_id
                    and "m2" in v
                ):
                    sketches.append(v)
        self.last_sketches = sketches
        rec = self._merge(window_id, sketches, canceled=canceled, pumps=pumps)
        self.history.append(rec)
        return rec

    def run_window(
        self, window_id: int, pump: Callable[[], None]
    ) -> WindowStats:
        return self.finish_window(self.start_window(window_id, pump))

    def _merge(
        self,
        window_id: int,
        sketches: list[dict[str, Any]],
        *,
        canceled: int,
        pumps: int,
    ) -> WindowStats:
        """Server side: one batched jit merge over the client axis."""
        if not sketches:
            return WindowStats(
                window_id, 0, canceled, pumps, 0, float("nan"), float("nan"),
                np.zeros((self.cfg.bins,), np.int64),
            )
        counts = np.asarray([s["count"] for s in sketches], np.float32)
        means = np.asarray([s["mean"] for s in sketches], np.float32)
        m2s = np.asarray([s["m2"] for s in sketches], np.float32)
        hists = np.asarray([s["hist"] for s in sketches], np.int64)
        c, mean, m2 = merge_moments(counts, means, m2s)
        hist = merge_histograms(hists)
        q_values = q_weights = None
        K = self.cfg.quantile_k
        if any(len(s.get("qsk") or ()) == K for s in sketches):
            qvals = np.full((len(sketches), K), np.nan, np.float32)
            for i, s in enumerate(sketches):
                q = s.get("qsk") or ()
                if len(q) == K:
                    qvals[i] = q
            q_values, q_weights = merge_quantile_sketches(qvals, counts)
        if c <= 0:
            # every vehicle sketched zero samples (e.g. an unknown signal):
            # there is no statistic to report, same as the no-sketches case
            mean, var = float("nan"), float("nan")
        else:
            var = m2 / c
        return WindowStats(
            window_id=window_id,
            participants=len(sketches),
            canceled=canceled,
            pumps=pumps,
            count=int(c),
            mean=mean,
            var=var,
            hist=hist,
            q_values=q_values,
            q_weights=q_weights,
        )

    # ------------------------------------------------------------------ #
    def format_table(self) -> str:
        head = (
            f"{'window':>6} {'clients':>8} {'canceled':>9} {'samples':>8} "
            f"{'mean':>9} {'std':>8} {'p50':>8} {'p90':>8}  histogram"
        )
        lines = [head]
        for r in self.history:
            total = max(1, int(r.hist.sum()))
            bar = "".join(
                " .:-=+*#%@"[min(9, int(10 * v / total))] for v in r.hist
            )
            lines.append(
                f"{r.window_id:>6} {r.participants:>8} {r.canceled:>9} "
                f"{r.count:>8} {r.mean:>9.3f} {r.std:>8.3f} "
                f"{r.quantile(0.5):>8.3f} {r.quantile(0.9):>8.3f}  [{bar}]"
            )
        return "\n".join(lines)
