"""Streaming data analytics over the fleet — the paper's second workload.

AutoSPADA's operational case study is not learning: it is *streaming
statistics over fuel-consumption signals* computed on-vehicle with only
compact summaries leaving the car (OODIDA's on-board/off-board analytics
split). This module is that workload on our platform:

1. an `AnalyticsDriver` window is one assignment to every online vehicle;
2. each vehicle's task container reads the last `window` observations of a
   signal from its signal plane view (`autospada.get_signal_window`),
   folds them through Welford's online mean/variance and a fixed-bin
   histogram, and publishes the resulting *sketch* — (count, mean, M2,
   bin counts), O(bins) bytes no matter how many samples were seen;
3. the server stacks all vehicles' sketches and merges them in one
   batched jit reduction (`repro.kernels.ops.merge_moments` /
   `merge_histograms` — the analytics twin of `batched_dequant_mean`),
   yielding exact fleet-level mean/variance/histogram as if every raw
   sample had been uploaded.

`merge_moments_reference` is the sequential pairwise (Chan et al.) merge,
kept as the oracle the batched path is tested against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Iterable

import numpy as np

from repro.core.user import User
from repro.fleet.rounds import pump_until_deadline
from repro.kernels.ops import merge_histograms, merge_moments

#: Payload template executed inside every vehicle's task container: fold a
#: signal window through Welford + fixed bins, publish the sketch only.
ANALYTICS_PAYLOAD = """
import autospada
import numpy as np

p = autospada.get_parameters()
sig = p["signal"]
xs = autospada.get_signal_window(sig, int(p["window"]))
x = np.asarray(xs, dtype=np.float64)
count = 0
mean = 0.0
m2 = 0.0
for v in x:
    count += 1
    d = float(v) - mean
    mean += d / count
    m2 += d * (float(v) - mean)
nb = int(p["bins"])
lo = float(p["lo"])
hi = float(p["hi"])
if count:
    width = (hi - lo) / nb
    idx = np.clip(((x - lo) / width).astype(np.int64), 0, nb - 1)
    hist = np.bincount(idx, minlength=nb)
else:
    hist = np.zeros((nb,), np.int64)
autospada.publish({
    "window_id": int(p["window_id"]),
    "signal": sig,
    "count": int(count),
    "mean": float(mean),
    "m2": float(m2),
    "hist": [int(v) for v in hist],
})
"""


@dataclasses.dataclass(frozen=True)
class AnalyticsConfig:
    """One streaming-statistics campaign over a vehicle signal."""

    signal: str = "Vehicle.FuelRate"
    window: int = 64        # on-vehicle samples folded per sketch
    bins: int = 16          # fixed-bin histogram resolution
    lo: float = 0.0         # histogram support (clipped at the edges);
    hi: float = 12.0        # default spans the drive-cycle fuel-rate range
    deadline_fraction: float = 0.9
    deadline_pumps: int | None = 64


@dataclasses.dataclass(frozen=True)
class WindowStats:
    """Fleet-level statistics of one analytics window."""

    window_id: int
    participants: int
    canceled: int
    pumps: int
    count: int          # pooled on-vehicle samples behind this window
    mean: float
    var: float          # population variance of the pooled samples
    hist: np.ndarray    # (bins,) pooled fixed-bin counts

    @property
    def std(self) -> float:
        return float(np.sqrt(max(self.var, 0.0)))


def merge_moments_reference(
    sketches: Iterable[tuple[float, float, float]]
) -> tuple[float, float, float]:
    """Sequential pairwise Chan merge of (count, mean, M2) sketches — the
    per-client loop the batched `kernels.ops.merge_moments` replaces, kept
    as the correctness oracle."""
    c, mean, m2 = 0.0, 0.0, 0.0
    for ci, mi, m2i in sketches:
        ci = float(ci)
        if ci <= 0:
            continue
        tot = c + ci
        delta = mi - mean
        mean += delta * ci / tot
        m2 += m2i + delta * delta * c * ci / tot
        c = tot
    return c, mean, m2


class AnalyticsDriver:
    """Runs windowed streaming-statistics assignments through the platform
    (the analytics sibling of `FederatedDriver`)."""

    def __init__(
        self,
        user: User,
        cfg: AnalyticsConfig,
        *,
        engine: Any = None,
        status_oracle: bool = False,
    ):
        self.user = user
        self.cfg = cfg
        #: unified event engine: window deadlines become heap entries; the
        #: quorum check reads AssignmentDoc.counts() (status events), with
        #: status_oracle=True restoring the dense statuses() scan
        self.engine = engine
        self.status_oracle = status_oracle
        self.history: list[WindowStats] = []
        #: raw per-vehicle sketches of the most recent window (tests replay
        #: the batched merge against the sequential reference with these)
        self.last_sketches: list[dict[str, Any]] = []

    def run_window(self, window_id: int, pump: Callable[[], None]) -> WindowStats:
        cfg = self.cfg
        clients = self.user.online_clients()
        payload = self.user.payload(
            ANALYTICS_PAYLOAD, name=f"analytics-w{window_id}"
        )
        # one immutable Parameters doc shared by every task — the sketch
        # spec is fleet-wide, unlike FedAvg's per-client data seeds
        params = self.user.parameter(
            {
                "signal": cfg.signal,
                "window": cfg.window,
                "bins": cfg.bins,
                "lo": cfg.lo,
                "hi": cfg.hi,
                "window_id": window_id,
            }
        )
        tasks = [self.user.task(c, payload, params) for c in clients]
        assign = self.user.assignment(
            f"analytics window {window_id}", tasks
        ).commit()
        need = max(1, int(len(clients) * cfg.deadline_fraction))
        pumps = pump_until_deadline(
            assign,
            len(clients),
            need=need,
            budget=cfg.deadline_pumps,
            pump=pump,
            engine=self.engine,
            status_oracle=self.status_oracle,
        )
        canceled = assign.cancel()
        sketches = []
        for values in assign.results().values():
            for v in values:
                if (
                    isinstance(v, dict)
                    and v.get("window_id") == window_id
                    and "m2" in v
                ):
                    sketches.append(v)
        self.last_sketches = sketches
        rec = self._merge(window_id, sketches, canceled=canceled, pumps=pumps)
        self.history.append(rec)
        return rec

    def _merge(
        self,
        window_id: int,
        sketches: list[dict[str, Any]],
        *,
        canceled: int,
        pumps: int,
    ) -> WindowStats:
        """Server side: one batched jit merge over the client axis."""
        if not sketches:
            return WindowStats(
                window_id, 0, canceled, pumps, 0, float("nan"), float("nan"),
                np.zeros((self.cfg.bins,), np.int64),
            )
        counts = np.asarray([s["count"] for s in sketches], np.float32)
        means = np.asarray([s["mean"] for s in sketches], np.float32)
        m2s = np.asarray([s["m2"] for s in sketches], np.float32)
        hists = np.asarray([s["hist"] for s in sketches], np.int64)
        c, mean, m2 = merge_moments(counts, means, m2s)
        hist = merge_histograms(hists)
        if c <= 0:
            # every vehicle sketched zero samples (e.g. an unknown signal):
            # there is no statistic to report, same as the no-sketches case
            mean, var = float("nan"), float("nan")
        else:
            var = m2 / c
        return WindowStats(
            window_id=window_id,
            participants=len(sketches),
            canceled=canceled,
            pumps=pumps,
            count=int(c),
            mean=mean,
            var=var,
            hist=hist,
        )

    # ------------------------------------------------------------------ #
    def format_table(self) -> str:
        head = (
            f"{'window':>6} {'clients':>8} {'canceled':>9} {'samples':>8} "
            f"{'mean':>9} {'std':>8}  histogram"
        )
        lines = [head]
        for r in self.history:
            total = max(1, int(r.hist.sum()))
            bar = "".join(
                " .:-=+*#%@"[min(9, int(10 * v / total))] for v in r.hist
            )
            lines.append(
                f"{r.window_id:>6} {r.participants:>8} {r.canceled:>9} "
                f"{r.count:>8} {r.mean:>9.3f} {r.std:>8.3f}  [{bar}]"
            )
        return "\n".join(lines)
