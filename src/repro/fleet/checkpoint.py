"""Durable fleet state: bit-for-bit checkpoint/restore (ROADMAP item 4).

`FleetCheckpoint.save` freezes an entire running fleet world — broker
queues and in-flight fault legs, statestore documents, per-vehicle
LocalDisk caches and client sync state, the event-engine heap, churn RNG
streams, the signal plane ring (host or device-sharded, gathered), fleet
metrics, and optionally a live workload driver plus its in-flight round —
into a versioned on-disk format: one deterministic JSON manifest plus
content-addressed arrays via `repro.train.checkpoint.BlobStore` (the same
npy-tree blobs training checkpoints use; nothing is duplicated).

`FleetCheckpoint.restore` rebuilds the world by constructing a fresh
`FleetSimulator` from the saved config and then surgically overwriting
every piece of state, so all object wiring (wake closures, plane views,
watchers) comes from ordinary construction and only *values* come from
disk. The restore is **elastic**: pass ``mesh=`` to reshard a sharded
signal plane onto a different device count — ring rows are re-padded to
the new capacity and device arrays are re-placed, with reads unchanged.

The contract, proven by `tests/test_checkpoint.py`: for any supported
config, ``run(a+b) == run(a) -> save -> restore -> run(b)`` bit-for-bit
on aggregates, broker counters, participants, pump counts, and plane
reads — including checkpoints taken mid-round with tasks in flight.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
from collections import deque
from functools import partial
from pathlib import Path
from typing import Any

import numpy as np

from repro.core import documents as _documents
from repro.core.broker import Message, Subscription, _is_exact
from repro.core.client import _LocalTask
from repro.core.documents import (
    Assignment,
    Parameters,
    Payload,
    Result,
    Task,
    TaskStatus,
)
from repro.core.statestore import ClientRecord, ClientStateSnapshot, TaskSyncInfo
from repro.core.user import AssignmentDoc, ParametersDoc, PayloadDoc, TaskDoc
from repro.fleet.analytics import (
    AnalyticsConfig,
    AnalyticsDriver,
    WindowInFlight,
    WindowStats,
)
from repro.fleet.engine import CalendarService, Entry, EngineService
from repro.fleet.federated import FedConfig
from repro.fleet.metrics import RoundMetrics, RoundProgress
from repro.fleet.rounds import DeadlinePump, FederatedDriver, RoundInFlight
from repro.fleet.service import DensePollService, FleetServiceScheduler
from repro.train.checkpoint import BlobStore

#: on-disk manifest format tag
FORMAT = "fleet-checkpoint"
#: bump whenever the manifest schema changes incompatibly
SCHEMA_VERSION = 1


class CheckpointError(Exception):
    """A checkpoint could not be written or read back faithfully."""


# --------------------------------------------------------------------------- #
# value codec: platform dataclasses + containers + ndarrays <-> JSON
# --------------------------------------------------------------------------- #

#: dataclasses that may appear inside checkpointed state; encoded as
#: ``[tag, [field values in dataclass field order]]``
_TAGGED = (
    Payload,
    Parameters,
    Task,
    Assignment,
    Result,
    ClientRecord,
    TaskSyncInfo,
    ClientStateSnapshot,
    Message,
    _LocalTask,
    RoundMetrics,
    RoundProgress,
    WindowStats,
    AnalyticsConfig,
    FedConfig,
)
_TAG_BY_TYPE = {t: t.__name__.lstrip("_") for t in _TAGGED}
_TYPE_BY_TAG = {tag: t for t, tag in _TAG_BY_TYPE.items()}
#: encoded field order per tagged type. Dataclasses use their field
#: order; `ClientRecord` is a slotted arena-view class (not a
#: dataclass), so its order is pinned to the constructor signature.
_FIELD_NAMES = {
    t: (
        ("client_id", "logical_clock", "online", "metadata")
        if t is ClientRecord
        else tuple(f.name for f in dataclasses.fields(t))
    )
    for t in _TAGGED
}


class _Codec:
    """Encode platform state to JSON-safe values; ndarrays are swapped
    for ``["ndarray", i]`` references into ``self.arrays`` (stored via
    BlobStore, so the manifest stays pure JSON)."""

    def __init__(self, arrays: list[np.ndarray] | None = None):
        self.arrays: list[np.ndarray] = list(arrays) if arrays else []

    def enc(self, obj: Any) -> Any:
        t = type(obj)
        if t in (type(None), bool, int, float, str):
            return obj
        if isinstance(obj, TaskStatus):  # str subclass: before tag dispatch
            return ["TaskStatus", obj.value]
        tag = _TAG_BY_TYPE.get(t)
        if tag is not None:
            return [tag, [self.enc(getattr(obj, name))
                          for name in _FIELD_NAMES[t]]]
        if t is list:
            return ["list", [self.enc(v) for v in obj]]
        if t is tuple:
            return ["tuple", [self.enc(v) for v in obj]]
        if t is dict:
            return ["dict", [[self.enc(k), self.enc(v)]
                             for k, v in obj.items()]]
        if t in (set, frozenset):
            return ["set", [self.enc(v) for v in sorted(obj)]]
        if isinstance(obj, np.ndarray):
            self.arrays.append(obj)
            return ["ndarray", len(self.arrays) - 1]
        if isinstance(obj, np.integer):
            return int(obj)
        if isinstance(obj, np.floating):
            return float(obj)
        if isinstance(obj, np.bool_):
            return bool(obj)
        if hasattr(obj, "__array__"):  # jax arrays from on-device kernels
            arr = np.asarray(obj)
            if arr.ndim == 0:
                return self.enc(arr.item())
            self.arrays.append(arr)
            return ["ndarray", len(self.arrays) - 1]
        raise CheckpointError(
            f"cannot checkpoint value of type {t.__name__}: {obj!r}"
        )

    def dec(self, obj: Any) -> Any:
        if not isinstance(obj, list):
            return obj
        if len(obj) != 2:
            raise CheckpointError(f"malformed encoded value: {obj!r}")
        tag, payload = obj
        if tag == "list":
            return [self.dec(v) for v in payload]
        if tag == "tuple":
            return tuple(self.dec(v) for v in payload)
        if tag == "dict":
            return {self.dec(k): self.dec(v) for k, v in payload}
        if tag == "set":
            return set(self.dec(v) for v in payload)
        if tag == "ndarray":
            return np.asarray(self.arrays[payload])
        if tag == "TaskStatus":
            return TaskStatus(payload)
        cls = _TYPE_BY_TAG.get(tag)
        if cls is not None:
            return cls(*[self.dec(v) for v in payload])
        raise CheckpointError(f"unknown value tag {tag!r}")


# --------------------------------------------------------------------------- #
# config
# --------------------------------------------------------------------------- #

#: config fields that must match the checkpoint exactly on restore —
#: they shape the state being overwritten
_STRUCTURAL = (
    "plane", "service", "churn", "engine",
    "n_clients", "scenario", "signal_history",
)
#: SimConfig mirror knobs stored as their enum .value strings
_KNOBS = ("plane", "service", "churn", "engine")


def _snap_config(cfg) -> dict:
    from repro.fleet.simulator import SimConfig

    out = {}
    for f in dataclasses.fields(SimConfig):
        if f.name == "backends":
            continue
        v = getattr(cfg, f.name)
        if f.name in _KNOBS:
            v = v.value if v is not None else None
        out[f.name] = v
    return out


def _restore_config(saved: dict, overrides: dict | None, mpath: Path, mesh):
    from repro.fleet.simulator import SimConfig

    if mesh is not None and saved.get("plane") != "sharded":
        raise CheckpointError(
            f"checkpoint {mpath}: mesh= is only valid for a sharded-plane "
            f"checkpoint (saved plane is {saved.get('plane')!r})"
        )
    overrides = dict(overrides or {})
    for name in _STRUCTURAL:
        if name in overrides:
            v = overrides.pop(name)
            v = getattr(v, "value", v)
            if v != saved.get(name):
                hint = (
                    " (pass mesh= to restore onto a different device layout)"
                    if name == "plane" else ""
                )
                raise CheckpointError(
                    f"checkpoint {mpath}: config field {name!r} is structural"
                    f" and cannot be overridden: saved {saved.get(name)!r},"
                    f" requested {v!r}{hint}"
                )
    cfg = SimConfig(**saved)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


# --------------------------------------------------------------------------- #
# broker
# --------------------------------------------------------------------------- #

def _snap_broker(broker, codec: _Codec) -> dict:
    subs = []
    for lst in broker._exact.values():
        subs.extend(lst)
    subs.extend(broker._wild)
    subs.sort(key=lambda s: s.order)
    return {
        "now": broker.now,
        "published": broker.published,
        "delivered": broker.delivered,
        "dropped": broker.dropped,
        "next_msg_id": broker._ids.n,
        "next_sub_order": broker._sub_order.n,
        "next_delay_order": broker._delay_order.n,
        "subs": [
            {
                "pattern": s.pattern,
                "qos": s.qos,
                "order": s.order,
                "reliable": s.reliable,
                "queue": codec.enc(list(s._queue)),
            }
            for s in subs
        ],
        "delayed": [
            {"due": due, "order": order, "sub": sub.order,
             "msg": codec.enc(msg)}
            for due, order, sub, msg in sorted(broker._delayed)
        ],
    }


def _restore_broker(broker, s: dict, codec: _Codec, mpath: Path) -> dict:
    broker.now = s["now"]
    broker.published = s["published"]
    broker.delivered = s["delivered"]
    broker.dropped = s["dropped"]
    broker._ids.n = s["next_msg_id"]
    broker._sub_order.n = s["next_sub_order"]
    broker._delay_order.n = s["next_delay_order"]
    broker._exact = {}
    broker._wild = []
    sub_map: dict[int, Subscription] = {}
    for e in s["subs"]:
        sub = Subscription(
            e["pattern"], e["qos"], order=e["order"], reliable=e["reliable"]
        )
        sub._queue.extend(codec.dec(e["queue"]))
        if _is_exact(e["pattern"]):
            broker._exact.setdefault(e["pattern"], []).append(sub)
        else:
            broker._wild.append(sub)
        sub_map[e["order"]] = sub
    delayed = []
    for e in s["delayed"]:
        sub = sub_map.get(e["sub"])
        if sub is None:
            raise CheckpointError(
                f"checkpoint {mpath}: delayed message references unknown "
                f"subscription order {e['sub']}"
            )
        delayed.append((e["due"], e["order"], sub, codec.dec(e["msg"])))
    heapq.heapify(delayed)
    broker._delayed = delayed
    return sub_map


# --------------------------------------------------------------------------- #
# statestore / documents
# --------------------------------------------------------------------------- #

_STORE_DICTS = (
    "_payloads", "_parameters", "_tasks", "_active_by_client",
    "_assignments", "_results", "_clients",
)


def _snap_store(store, codec: _Codec) -> dict:
    return {name: codec.enc(getattr(store, name)) for name in _STORE_DICTS}


def _restore_store(store, s: dict, codec: _Codec) -> None:
    for name in _STORE_DICTS:
        setattr(store, name, codec.dec(s[name]))
    # _watchers untouched: the fresh server watcher wiring stands
    if store.columns is not None:
        # decoded ClientRecords are unbound (local scalars); rebind them
        # to the arena — the columns section overwrites the arena last,
        # so these writes only re-establish the view wiring
        store.attach_columns(store.columns)


# --------------------------------------------------------------------------- #
# vehicles (LocalDisk + EdgeClient volatile state)
# --------------------------------------------------------------------------- #

_DISK_FIELDS = (
    "payload_cache", "parameters_cache", "unacked",
    "next_seq", "terminal", "task_state", "done",
)


def _snap_vehicles(pool, codec: _Codec) -> dict:
    out = {}
    for cid, v in pool.vehicles.items():
        d = v.disk
        entry: dict[str, Any] = {
            "index": v.metadata["index"],
            "online": v.client is not None,
            "disk": {f: codec.enc(getattr(d, f)) for f in _DISK_FIELDS},
        }
        if v.client is not None:
            c = v.client
            for lt in c.local_tasks.values():
                if lt.container is not None:
                    raise CheckpointError(
                        f"client {cid} has a live container thread; "
                        "checkpoint requires inline containers"
                    )
            entry["client"] = {
                "ts": c.ts,
                "tasks": codec.enc(c.tasks),
                "local_tasks": codec.enc(c.local_tasks),
                "syncing_state": c.syncing_state,
                "dirty_state": c.dirty_state,
                "ops": codec.enc(list(c._ops)),
                "container_events": codec.enc(list(c._container_events)),
                "registered": bool(getattr(c, "_registered", True)),
                "rpc_failures": c.rpc_failures,
                "sub": c._sub.order if c._sub is not None else None,
            }
        out[cid] = entry
    return out


def _apply_power_state(sim, saved: dict) -> None:
    """Align the fresh fleet's power state with the checkpoint BEFORE
    any state is overwritten — power_off touches broker/store/plane/
    churn/service, and all those side effects get overwritten later."""
    for cid in sorted(saved):
        if not saved[cid]["online"]:
            sim.pool.power_off(cid)


def _restore_vehicles(sim, saved: dict, sub_map: dict, codec: _Codec,
                      mpath: Path) -> None:
    pool = sim.pool
    if set(saved) != set(pool.vehicles):
        raise CheckpointError(
            f"checkpoint {mpath}: vehicle ids do not match the fleet "
            f"(saved {len(saved)}, live {len(pool.vehicles)})"
        )
    for cid in sorted(saved):
        e = saved[cid]
        v = pool.vehicles[cid]
        d = v.disk
        for f in _DISK_FIELDS:
            setattr(d, f, codec.dec(e["disk"][f]))
        if not e["online"]:
            continue
        c = v.client
        ce = e["client"]
        c.ts = ce["ts"]
        c.tasks = codec.dec(ce["tasks"])
        c.local_tasks = codec.dec(ce["local_tasks"])
        c.syncing_state = ce["syncing_state"]
        c.dirty_state = ce["dirty_state"]
        c._ops = codec.dec(ce["ops"])
        c._container_events = deque(codec.dec(ce["container_events"]))
        c._registered = ce["registered"]
        c.rpc_failures = ce["rpc_failures"]
        if ce["sub"] is None:
            c._sub = None
        else:
            sub = sub_map.get(ce["sub"])
            if sub is None:
                raise CheckpointError(
                    f"checkpoint {mpath}: client {cid} references unknown "
                    f"subscription order {ce['sub']}"
                )
            c._sub = sub
            c._sub.wake = c._wake_cb


# --------------------------------------------------------------------------- #
# event engine
# --------------------------------------------------------------------------- #

def _snap_engine(engine) -> tuple[dict, dict[int, int]]:
    entries = []
    id_to_seq: dict[int, int] = {}
    for at, phase, key, seq, entry in sorted(engine._heap):
        if entry.canceled:
            continue
        fn = entry.fn
        if fn is None:
            kind, args = "timer", []
        elif isinstance(fn, partial):
            name = fn.func.__name__
            if name == "_fire":
                kind = "churn"
            elif name == "_fire_resync":
                kind = "resync"
            elif name == "_fire_release":
                kind = "release"
            else:
                raise CheckpointError(
                    f"cannot checkpoint engine callback {name!r}"
                )
            args = [a if isinstance(a, str) else int(a) for a in fn.args]
        else:
            raise CheckpointError(
                f"cannot checkpoint engine callback {fn!r}"
            )
        id_to_seq[id(entry)] = seq
        entries.append({
            "at": at, "phase": phase, "key": key, "seq": seq,
            "kind": kind, "args": args,
        })
    return {"now": engine.now, "next_seq": engine._seq.n,
            "entries": entries}, id_to_seq


def _restore_engine(sim, s: dict, mpath: Path) -> dict[int, Entry]:
    eng = sim.engine
    eng.now = s["now"]
    eng._seq.n = s["next_seq"]
    seq_map: dict[int, Entry] = {}
    heap = []
    for e in s["entries"]:
        kind, args = e["kind"], e["args"]
        if kind == "timer":
            fn = None
        elif kind == "churn":
            fn = partial(sim.churn._fire, args[0], int(args[1]))
        elif kind in ("resync", "release"):
            if not isinstance(sim.service, EngineService):
                raise CheckpointError(
                    f"checkpoint {mpath}: engine entry kind {kind!r} "
                    "requires the engine service backend"
                )
            target = (sim.service._fire_resync if kind == "resync"
                      else sim.service._fire_release)
            fn = partial(target, int(args[0]), int(args[1]))
        else:
            raise CheckpointError(
                f"checkpoint {mpath}: unknown engine entry kind {kind!r}"
            )
        entry = Entry(e["at"], e["phase"], e["key"], fn)
        seq_map[e["seq"]] = entry
        heap.append((e["at"], e["phase"], e["key"], e["seq"], entry))
    heapq.heapify(heap)
    eng._heap = heap
    return seq_map


# --------------------------------------------------------------------------- #
# churn
# --------------------------------------------------------------------------- #

def _snap_churn(churn) -> dict:
    return {
        "now": churn.now,
        "vehicles": {
            cid: {
                "index": churn._index[cid],
                "online": churn._online[cid],
                "next": churn._next.get(cid),
                "rng": churn._rng[cid].bit_generator.state,
            }
            for cid in sorted(churn._online)
        },
    }


def _restore_churn(sim, s: dict, mpath: Path) -> None:
    ch = sim.churn
    ch.now = s["now"]
    for cid, e in s["vehicles"].items():
        if cid not in ch._online:
            raise CheckpointError(
                f"checkpoint {mpath}: churn references unknown vehicle {cid}"
            )
        ch._index[cid] = e["index"]
        ch._online[cid] = e["online"]
        if e["next"] is None:
            ch._next.pop(cid, None)
        else:
            ch._next[cid] = e["next"]
        ch._rng[cid].bit_generator.state = e["rng"]
    if ch._engine is None and ch._use_heap:
        heap = [(t, ch._index[cid], cid) for cid, t in ch._next.items()
                if t is not None]
        heapq.heapify(heap)
        ch._heap = heap


# --------------------------------------------------------------------------- #
# service
# --------------------------------------------------------------------------- #

def _snap_service(svc, codec: _Codec) -> dict:
    if isinstance(svc, CalendarService):  # deepest subclass first
        # the refill schedule lives in lane membership bits, not the
        # heap: save them directly (resync membership also equals the
        # power state, but saving it keeps restore order-independent)
        n = svc._capacity
        return {
            "kind": "calendar",
            "runnable": [bool(b) for b in svc._runnable],
            "hot": [int(i) for i in svc._hot],
            "due": [int(i) for i in svc._due],
            "resync": [int(i) for i in
                       np.nonzero(svc._resync_lane._on[:n])[0]],
            "release": [int(i) for i in
                        np.nonzero(svc._release_lane._on[:n])[0]],
        }
    if isinstance(svc, EngineService):  # subclass check first
        return {
            "kind": "engine",
            "runnable": [bool(b) for b in svc._runnable],
            "hot": [int(i) for i in svc._hot],
            "due": [int(i) for i in svc._due],
            "resync_at": sorted([int(k), int(v)]
                                for k, v in svc._resync_at.items()),
            "release_at": sorted([int(k), int(v)]
                                 for k, v in svc._release_at.items()),
        }
    if isinstance(svc, DensePollService):
        return {"kind": "dense"}
    if isinstance(svc, FleetServiceScheduler):
        return {"kind": "scheduler",
                "runnable": [bool(b) for b in svc._runnable]}
    raise CheckpointError(
        f"cannot checkpoint service of type {type(svc).__name__}"
    )


def _restore_service(sim, s: dict, mpath: Path) -> None:
    svc = sim.service
    kind = s["kind"]
    if kind == "dense":
        if not isinstance(svc, DensePollService):
            raise CheckpointError(
                f"checkpoint {mpath}: service kind mismatch: saved 'dense', "
                f"live {type(svc).__name__}"
            )
        return
    runnable = np.asarray(s["runnable"], dtype=bool)
    if runnable.shape != svc._runnable.shape:
        raise CheckpointError(
            f"checkpoint {mpath}: field 'service.runnable' has shape "
            f"{runnable.shape}, live scheduler expects {svc._runnable.shape}"
        )
    svc._runnable[:] = runnable
    if kind == "calendar":
        if not isinstance(svc, CalendarService):
            raise CheckpointError(
                f"checkpoint {mpath}: service kind mismatch: saved "
                f"'calendar', live {type(svc).__name__}"
            )
        svc._hot = deque(int(i) for i in s["hot"])
        svc._due = [int(i) for i in s["due"]]
        for lane, key in ((svc._resync_lane, "resync"),
                          (svc._release_lane, "release")):
            lane._on[:] = False
            for i in s[key]:
                lane.set_member(int(i), True)
    elif kind == "engine":
        if not isinstance(svc, EngineService) or isinstance(
            svc, CalendarService
        ):
            raise CheckpointError(
                f"checkpoint {mpath}: service kind mismatch: saved 'engine', "
                f"live {type(svc).__name__}"
            )
        svc._hot = deque(int(i) for i in s["hot"])
        svc._due = [int(i) for i in s["due"]]
        svc._resync_at = {int(k): int(v) for k, v in s["resync_at"]}
        svc._release_at = {int(k): int(v) for k, v in s["release_at"]}


# --------------------------------------------------------------------------- #
# columnar per-client arena
# --------------------------------------------------------------------------- #

def _snap_columns(sim, codec: _Codec) -> dict | None:
    cols = getattr(sim, "columns", None)
    if cols is None:
        return None
    return {
        "ids": list(cols.client_ids()),
        "arrays": {name: codec.enc(arr)
                   for name, arr in cols.snapshot().items()},
    }


def _restore_columns(sim, s: dict | None, codec: _Codec, mpath: Path) -> None:
    """Overwrite the arena from its snapshot — applied LAST, so the
    column values (clocks, power, timestamps, gating bits) written
    through viewer properties during the earlier restore passes are
    superseded by the authoritative saved arrays."""
    cols = getattr(sim, "columns", None)
    if s is None or cols is None:
        return
    ids = list(s["ids"])
    live = list(cols.client_ids())
    if ids != live:
        raise CheckpointError(
            f"checkpoint {mpath}: columns row registry does not match the "
            f"fresh fleet (saved {len(ids)} rows, live {len(live)})"
        )
    cols.load({name: codec.dec(v) for name, v in s["arrays"].items()}, ids)


# --------------------------------------------------------------------------- #
# signal plane
# --------------------------------------------------------------------------- #

def _snap_plane(plane, codec: _Codec) -> dict:
    from repro.core.plane_sharded import ShardedSignalPlane

    n = plane.n_clients
    if isinstance(plane, ShardedSignalPlane):
        ring = np.asarray(plane._dhist)[:, :n, :]
        values = np.asarray(plane._dvalues)[:n]
        backend = "sharded"
    else:
        ring = plane._hist[:, :n, :].copy()
        values = plane._values[:n].copy()
        backend = "host"
    return {
        "backend": backend,
        "t": plane.t,
        "hist_len": plane._hist_len,
        "n_clients": n,
        "ring": codec.enc(np.ascontiguousarray(ring)),
        "values": codec.enc(np.ascontiguousarray(values)),
        "offline": codec.enc(np.array(plane._offline[:n])),
    }


def _reshard_plane(sim, mesh) -> None:
    """Rebuild the sharded plane on a new mesh; views follow the swap."""
    from repro.fleet.scenarios import build_plane

    cfg = sim.cfg
    plane = build_plane(
        cfg.scenario, cfg.n_clients, cfg.seed,
        history=cfg.signal_history, plane="sharded", mesh=mesh,
    )
    sim.plane = plane
    sim.pool.plane = plane
    for v in sim.pool.vehicles.values():
        v.signals.plane = plane


def _restore_plane(sim, s: dict, codec: _Codec, mpath: Path) -> None:
    plane = sim.plane
    n = s["n_clients"]
    if plane.n_clients != n:
        raise CheckpointError(
            f"checkpoint {mpath}: field 'plane.n_clients' is {n}, live "
            f"plane has {plane.n_clients}"
        )
    ring = codec.dec(s["ring"])
    values = codec.dec(s["values"])
    offline = codec.dec(s["offline"])
    want = (plane._hist_cap, n, len(plane.names))
    if ring.shape != want:
        raise CheckpointError(
            f"checkpoint {mpath}: field 'plane.ring' has shape "
            f"{ring.shape}, expected {want}"
        )
    from repro.core.plane_sharded import ShardedSignalPlane

    if isinstance(plane, ShardedSignalPlane):
        import jax
        import jax.numpy as jnp

        from repro.sharding import fleet as fleet_sharding

        cap = plane._capacity
        full = np.full((plane._hist_cap, cap, len(plane.names)), np.nan,
                       dtype=np.float32)
        full[:, :n, :] = ring
        plane._dhist = jax.device_put(
            full, fleet_sharding.ring_sharding(plane.mesh)
        )
        plane.t = s["t"]
        plane._dvalues = plane._values_fn(jnp.int32(s["t"]))
        off = np.zeros(cap, dtype=bool)
        off[:n] = offline
        plane._offline = off
        plane._doffline = jax.device_put(
            off, fleet_sharding.mask_sharding(plane.mesh)
        )
        plane._mask_dirty = False
        plane._hist_len = s["hist_len"]
        plane._values_dirty = True
        plane._hist_dirty = True
        plane._sketch_cache.clear()
    else:
        if s["backend"] == "sharded":
            raise CheckpointError(
                f"checkpoint {mpath}: field 'plane.backend' is 'sharded' "
                "but the live plane is host-resident; restore with the "
                "saved plane backend (optionally passing mesh=)"
            )
        plane._values[:n] = np.asarray(values, dtype=np.float32)
        plane._hist[:, :n, :] = np.asarray(ring, dtype=np.float32)
        plane._offline[:n] = offline
        plane.t = s["t"]
        plane._hist_len = s["hist_len"]
        plane._sketch_cache.clear()


# --------------------------------------------------------------------------- #
# workload driver + in-flight round
# --------------------------------------------------------------------------- #

def _snap_driver(driver, codec: _Codec) -> dict:
    if isinstance(driver, FederatedDriver):
        if driver.n_samples_fn is not None:
            raise CheckpointError(
                "FederatedDriver.n_samples_fn callables are not serializable"
            )
        return {
            "kind": "federated",
            "cfg": codec.enc(driver.cfg),
            "w": codec.enc(driver.w),
            "w_true": codec.enc(np.asarray(driver.w_true)),
            "bias_signal": driver.bias_signal,
            "n_samples": driver.n_samples,
            "payload_source": driver.payload_source,
            "status_oracle": driver.status_oracle,
            "has_metrics": driver.metrics is not None,
            "history": codec.enc(driver.history),
            "last_msgs": codec.enc(driver.last_msgs),
        }
    if isinstance(driver, AnalyticsDriver):
        return {
            "kind": "analytics",
            "cfg": codec.enc(driver.cfg),
            "status_oracle": driver.status_oracle,
            "has_metrics": driver.metrics is not None,
            "history": codec.enc(driver.history),
            "last_sketches": codec.enc(driver.last_sketches),
        }
    raise CheckpointError(
        f"cannot checkpoint driver of type {type(driver).__name__}"
    )


def _restore_driver(sim, d: dict, codec: _Codec):
    kind = d["kind"]
    if kind == "federated":
        w = codec.dec(d["w"])
        w_true = codec.dec(d["w_true"])
        drv = FederatedDriver(
            sim.user,
            codec.dec(d["cfg"]),
            dim=int(w.shape[0]),
            w_true=w_true,
            bias_signal=d["bias_signal"],
            n_samples=d["n_samples"],
            payload_source=d["payload_source"],
            engine=sim.engine,
            status_oracle=d["status_oracle"],
            metrics=sim.metrics if d["has_metrics"] else None,
        )
        drv.w = np.asarray(w, dtype=np.float32)
        drv.history = codec.dec(d["history"])
        drv.last_msgs = codec.dec(d["last_msgs"])
        return drv
    if kind == "analytics":
        drv = AnalyticsDriver(
            sim.user,
            codec.dec(d["cfg"]),
            engine=sim.engine,
            status_oracle=d["status_oracle"],
            metrics=sim.metrics if d["has_metrics"] else None,
        )
        drv.history = codec.dec(d["history"])
        drv.last_sketches = codec.dec(d["last_sketches"])
        return drv
    raise CheckpointError(f"unknown driver kind {kind!r}")


def _snap_rif(rif, id_to_seq: dict[int, int], codec: _Codec) -> dict:
    doc = rif.assign
    if doc.assignment_id is None:
        raise CheckpointError(
            "in-flight round's assignment is not committed; checkpoint "
            "after start_round/start_window"
        )
    p = rif.pump
    dl = p.deadline
    return {
        "round": getattr(rif, "rnd", None) if isinstance(rif, RoundInFlight)
                 else rif.window_id,
        "n_clients": rif.n_clients,
        "assign": {
            "name": doc.name,
            "assignment_id": doc.assignment_id,
            "tasks": [
                {
                    "client_id": t.client_id,
                    "payload_id": t.payload.payload_id,
                    "parameters_id": (t.parameters.parameters_id
                                      if t.parameters is not None else None),
                    "task_id": t.task_id,
                }
                for t in doc.tasks
            ],
            "terminal": codec.enc(doc._terminal),
            "n_finished": doc._n_finished,
            "n_error": doc._n_error,
            "n_canceled": doc._n_canceled,
            "task_ids": codec.enc(doc._task_ids),
            "results_sub": doc._results_sub.order,
            "status_sub": doc._status_sub.order,
        },
        "pump": {
            "need": p.need,
            "budget": p.budget,
            "pumps": p.pumps,
            "closed": p.closed,
            "has_on_counts": p.on_counts is not None,
            "deadline": None if dl is None else {
                "at": dl.at, "phase": dl.phase, "key": dl.key,
                "fired": dl.fired, "canceled": dl.canceled,
                "seq": id_to_seq.get(id(dl)),
            },
        },
    }


def _restore_rif(sim, driver, r: dict, sub_map: dict, seq_map: dict,
                 codec: _Codec, mpath: Path):
    a = r["assign"]
    doc = AssignmentDoc(sim.user, a["name"], tasks=[])
    doc.assignment_id = a["assignment_id"]
    for te in a["tasks"]:
        pd = PayloadDoc(sim.user, source="", name="",
                        payload_id=te["payload_id"])
        prm = (ParametersDoc(sim.user, value=None,
                             parameters_id=te["parameters_id"])
               if te["parameters_id"] is not None else None)
        doc.tasks.append(
            TaskDoc(sim.user, te["client_id"], pd, prm,
                    task_id=te["task_id"])
        )
    doc._terminal = codec.dec(a["terminal"])
    doc._n_finished = a["n_finished"]
    doc._n_error = a["n_error"]
    doc._n_canceled = a["n_canceled"]
    doc._task_ids = codec.dec(a["task_ids"])
    for attr, key in (("_results_sub", "results_sub"),
                      ("_status_sub", "status_sub")):
        sub = sub_map.get(a[key])
        if sub is None:
            raise CheckpointError(
                f"checkpoint {mpath}: in-flight assignment references "
                f"unknown subscription order {a[key]}"
            )
        setattr(doc, attr, sub)
    doc._status_sub.wake = doc._absorb_status_events
    doc._absorb_status_events()

    ps = r["pump"]
    p = DeadlinePump.__new__(DeadlinePump)
    p.assign = doc
    p.n_tasks = r["n_clients"]
    p.need = ps["need"]
    p.budget = ps["budget"]
    p.pump = sim.tick
    p.engine = sim.engine
    p.status_oracle = driver.status_oracle
    p.on_counts = (sim.metrics.update_progress
                   if ps["has_on_counts"] else None)
    p.hard = p.budget if p.budget is not None else 100_000
    p.pumps = ps["pumps"]
    p.closed = ps["closed"]
    dl = ps["deadline"]
    if dl is None:
        p.deadline = None
    elif dl["seq"] is not None and dl["seq"] in seq_map:
        p.deadline = seq_map[dl["seq"]]  # same Entry the heap holds
    else:
        entry = Entry(dl["at"], dl["phase"], dl["key"], None)
        entry.fired = dl["fired"]
        entry.canceled = dl["canceled"]
        p.deadline = entry

    if isinstance(driver, AnalyticsDriver):
        return WindowInFlight(window_id=r["round"],
                              n_clients=r["n_clients"], assign=doc, pump=p)
    return RoundInFlight(rnd=r["round"], n_clients=r["n_clients"],
                         assign=doc, pump=p)


# --------------------------------------------------------------------------- #
# the public facade
# --------------------------------------------------------------------------- #

class FleetCheckpoint:
    """Versioned whole-platform checkpoints on disk.

    Layout: ``{path}/manifest.json`` (deterministic JSON, sorted keys)
    plus ``{path}/arrays/`` — a `BlobStore` of content-addressed npy
    leaves holding every ndarray referenced from the manifest.
    """

    @staticmethod
    def save(sim, path: str | Path, *, driver=None, rif=None,
             previous: str | Path | None = None) -> Path:
        """Freeze the fleet at ``path``. With ``previous`` (the last
        checkpoint of the same run), unchanged content-addressed arrays
        are hardlinked from it instead of rewritten — periodic saves of
        a mostly-idle mega-fleet cost I/O proportional to what changed
        (the launch hook threads this automatically)."""
        if rif is not None and driver is None:
            raise CheckpointError(
                "cannot checkpoint an in-flight round without its driver"
            )
        if sim.plane is None:
            raise CheckpointError(
                "cannot checkpoint a simulator with an external signal_fn "
                "plane"
            )
        cfg = sim.cfg
        if len(sim.pool.vehicles) != cfg.n_clients:
            raise CheckpointError(
                f"fleet size {len(sim.pool.vehicles)} != configured "
                f"n_clients {cfg.n_clients}; grown fleets are unsupported"
            )
        codec = _Codec()
        if sim.engine is not None:
            engine_state, id_to_seq = _snap_engine(sim.engine)
        else:
            engine_state, id_to_seq = None, {}
        state = {
            "config": _snap_config(cfg),
            "t": sim.t,
            "documents_next_id": _documents._ids.n,
            "broker": _snap_broker(sim.broker, codec),
            "engine": engine_state,
            "churn": _snap_churn(sim.churn),
            "store": _snap_store(sim.store, codec),
            "vehicles": _snap_vehicles(sim.pool, codec),
            "plane": _snap_plane(sim.plane, codec),
            "service": _snap_service(sim.service, codec),
            "columns": _snap_columns(sim, codec),
            "metrics": {
                "rounds": codec.enc(sim.metrics.rounds),
                "progress": codec.enc(sim.metrics.progress),
            },
            "driver": _snap_driver(driver, codec) if driver is not None
                      else None,
            "rif": _snap_rif(rif, id_to_seq, codec) if rif is not None
                   else None,
        }
        path = Path(path)
        path.mkdir(parents=True, exist_ok=True)
        BlobStore(path / "arrays").put(
            "arrays", codec.arrays,
            link_from=None if previous is None else Path(previous) / "arrays",
        )
        manifest = {"format": FORMAT, "schema": SCHEMA_VERSION,
                    "state": state}
        (path / "manifest.json").write_text(
            json.dumps(manifest, sort_keys=True, separators=(",", ":"))
        )
        return path

    @staticmethod
    def restore(path: str | Path, *, config_overrides: dict | None = None,
                mesh=None):
        from repro.fleet.simulator import FleetSimulator

        path = Path(path)
        mpath = path / "manifest.json"
        if not mpath.exists():
            raise CheckpointError(f"checkpoint manifest missing: {mpath}")
        try:
            manifest = json.loads(mpath.read_text())
        except ValueError as e:
            raise CheckpointError(
                f"checkpoint manifest corrupt: {mpath}: {e}"
            ) from e
        fmt = manifest.get("format")
        if fmt != FORMAT:
            raise CheckpointError(
                f"checkpoint {mpath} has format {fmt!r}, expected {FORMAT!r}"
            )
        schema = manifest.get("schema")
        if schema != SCHEMA_VERSION:
            raise CheckpointError(
                f"checkpoint {mpath} has schema version {schema!r}; this "
                f"build reads {SCHEMA_VERSION}"
            )
        try:
            arrays = BlobStore(path / "arrays").get("arrays")
        except (FileNotFoundError, ValueError) as e:
            raise CheckpointError(
                f"checkpoint {mpath} arrays unreadable: {e}"
            ) from e
        codec = _Codec(arrays)
        state = manifest["state"]

        cfg = _restore_config(state["config"], config_overrides, mpath, mesh)
        sim = FleetSimulator(cfg)
        if mesh is not None:
            _reshard_plane(sim, mesh)
        _apply_power_state(sim, state["vehicles"])
        sub_map = _restore_broker(sim.broker, state["broker"], codec, mpath)
        _restore_store(sim.store, state["store"], codec)
        _documents._ids.n = state["documents_next_id"]
        _restore_vehicles(sim, state["vehicles"], sub_map, codec, mpath)
        if state["engine"] is not None:
            if sim.engine is None:
                raise CheckpointError(
                    f"checkpoint {mpath}: saved engine state but the live "
                    "config has no event engine"
                )
            seq_map = _restore_engine(sim, state["engine"], mpath)
        else:
            seq_map = {}
        _restore_churn(sim, state["churn"], mpath)
        _restore_service(sim, state["service"], mpath)
        _restore_plane(sim, state["plane"], codec, mpath)
        sim.metrics.rounds = codec.dec(state["metrics"]["rounds"])
        sim.metrics.progress = codec.dec(state["metrics"]["progress"])
        _restore_columns(sim, state.get("columns"), codec, mpath)
        sim.t = state["t"]

        driver = None
        rif = None
        if state["driver"] is not None:
            driver = _restore_driver(sim, state["driver"], codec)
        if state["rif"] is not None:
            if driver is None:
                raise CheckpointError(
                    f"checkpoint {mpath}: in-flight round saved without a "
                    "driver"
                )
            rif = _restore_rif(sim, driver, state["rif"], sub_map, seq_map,
                               codec, mpath)
        return sim, driver, rif
