"""Platform-driven federated rounds.

One round == one AutoSPADA assignment (DESIGN.md §2):

1. the user commits an assignment whose tasks carry the current global
   model in the Parameters document (paper §4.1: "distribute a model to
   many clients");
2. each online vehicle's task container trains locally on data derived
   from its signals and publishes a quantized delta as an ordinary result;
3. the driver awaits the deadline fraction of FINISHED tasks, cancels the
   stragglers (only ACTIVE tasks can be canceled — the lifecycle rules do
   the bookkeeping), and FedAvg-aggregates what arrived.

Deltas travel as base64-packed int8 + scales inside JSON results — the
same network-budget discipline the paper applies with protobuf/MQTT.
"""
from __future__ import annotations

import base64
import dataclasses
import math
from typing import Any, Callable

import numpy as np

from repro.core.documents import TaskStatus
from repro.core.user import AssignmentDoc, User
from repro.fleet.federated import FedConfig


# --------------------------------------------------------------------- #
# shared deadline-driven assignment pump (FedAvg rounds, analytics       #
# windows — every platform workload closes rounds the same way)          #
# --------------------------------------------------------------------- #
class DeadlinePump:
    """Resumable deadline-driven assignment pump.

    Pumps the world until `need` tasks are FINISHED, every task is
    terminal, or the deadline passes (the paper's wall-clock round
    deadline: close on time with whatever arrived).

    The quorum check reads `AssignmentDoc.counts()` — O(1) counters
    maintained by status events — never a per-pump `statuses()` rebuild.
    With an `engine`, the deadline itself is a heap entry: the round
    closes when the timer fires (identical to the pump budget whenever
    one pump == one tick, i.e. every driver in this repo).
    `status_oracle=True` restores the dense per-pump statuses() scan —
    the parity oracle the engine path is tested against bit-for-bit.

    `on_counts` (if given) sees every per-pump `TaskCounts` snapshot —
    the free live-progress feed (`FleetMetrics.update_progress`): the
    quorum check already holds the counters, so gauges cost zero extra
    store scans. The oracle branch feeds it from its statuses() scan,
    keeping the two paths observationally identical.

    The pump is an explicit object (not a loop) so a round can be
    suspended *mid-flight*: `step()` advances one pump and reports
    whether the round closed, and all progress lives in plain fields
    (`pumps`, `closed`, `deadline`) that `repro.fleet.checkpoint`
    snapshots and restores bit-for-bit."""

    def __init__(
        self,
        assign: AssignmentDoc,
        n_tasks: int,
        *,
        need: int,
        budget: int | None,
        pump: Callable[[], None],
        engine: Any = None,
        status_oracle: bool = False,
        on_counts: Callable[[Any], None] | None = None,
    ):
        self.assign = assign
        self.n_tasks = n_tasks
        self.need = need
        self.budget = budget
        self.pump = pump
        self.engine = engine
        self.status_oracle = status_oracle
        self.on_counts = on_counts
        self.hard = budget if budget is not None else 100_000
        self.pumps = 0
        self.closed = False
        self.deadline = None
        if not status_oracle and engine is not None and budget is not None:
            self.deadline = engine.schedule(engine.now + budget)

    def step(self) -> bool:
        """One pump of the world plus one quorum check. Returns True once
        the round is closed (idempotent after that)."""
        if self.closed:
            return True
        if self.status_oracle:
            return self._step_oracle()
        self.pumps += 1
        self.pump()
        c = self.assign.counts()
        if self.on_counts is not None:
            self.on_counts(c)
        if c.finished >= self.need or c.active == 0:
            if self.deadline is not None:
                self.deadline.cancel()
            self.closed = True
        elif self.deadline is not None:
            if self.deadline.fired:
                self.closed = True
        elif self.pumps >= self.hard:
            if self.budget is None:  # pragma: no cover
                raise TimeoutError(
                    "assignment did not reach its deadline quorum"
                )
            self.closed = True
        return self.closed

    def _step_oracle(self) -> bool:
        from repro.core.user import TaskCounts

        # budget exhaustion is checked *before* pumping: the original
        # `for pumps in range(1, hard + 1)` loop never pumped past `hard`
        # (and never pumped at all for hard == 0)
        if self.pumps >= self.hard:
            if self.budget is None:  # pragma: no cover
                raise TimeoutError(
                    "assignment did not reach its deadline quorum"
                )
            self.closed = True
            return True
        self.pumps += 1
        self.pump()
        statuses = self.assign.statuses()
        done = sum(s == TaskStatus.FINISHED.value for s in statuses.values())
        err = sum(s == TaskStatus.ERROR.value for s in statuses.values())
        canc = sum(s == TaskStatus.CANCELED.value for s in statuses.values())
        dead = err + canc
        if self.on_counts is not None:
            self.on_counts(
                TaskCounts(
                    finished=done,
                    error=err,
                    canceled=canc,
                    active=self.n_tasks - done - dead,
                )
            )
        if done >= self.need or done + dead == self.n_tasks:
            self.closed = True
        return self.closed

    def run(self) -> int:
        """Pump to close; returns total pumps used (across suspensions)."""
        while not self.step():
            pass
        return self.pumps


def pump_until_deadline(
    assign: AssignmentDoc,
    n_tasks: int,
    *,
    need: int,
    budget: int | None,
    pump: Callable[[], None],
    engine: Any = None,
    status_oracle: bool = False,
    on_counts: Callable[[Any], None] | None = None,
) -> int:
    """One-shot wrapper over `DeadlinePump`: pump to close, return pumps
    used. Raises TimeoutError only for unbounded waits that never
    quiesce."""
    return DeadlinePump(
        assign,
        n_tasks,
        need=need,
        budget=budget,
        pump=pump,
        engine=engine,
        status_oracle=status_oracle,
        on_counts=on_counts,
    ).run()


# --------------------------------------------------------------------- #
# wire format: int8 delta <-> JSON-safe dict                             #
# --------------------------------------------------------------------- #
def pack_delta(flat: np.ndarray, row: int = 4096) -> dict[str, Any]:
    from repro.kernels.ref import quantize_int8_ref

    n = flat.shape[0]
    pad = (-n) % row
    x = np.pad(flat.astype(np.float32), (0, pad)).reshape(-1, row)
    q, s = quantize_int8_ref(x)
    return {
        "q": base64.b64encode(np.asarray(q, np.int8).tobytes()).decode(),
        "s": [float(v) for v in np.asarray(s)[:, 0]],
        "n": n,
        "row": row,
    }


def unpack_delta(msg: dict[str, Any]) -> np.ndarray:
    q = np.frombuffer(base64.b64decode(msg["q"]), np.int8).reshape(
        -1, msg["row"]
    )
    s = np.asarray(msg["s"], np.float32)[:, None]
    return (q.astype(np.float32) * s).reshape(-1)[: msg["n"]]


# --------------------------------------------------------------------- #
# vectorized aggregation: stack every client's packed delta, dequantize  #
# and weighted-sum in one batched JAX op                                 #
# --------------------------------------------------------------------- #
def stack_deltas(
    msgs: list[dict[str, Any]]
) -> tuple[np.ndarray, np.ndarray, int, int] | None:
    """Stack homogeneous packed-delta messages into (N, R, row) int8 q and
    (N, R) f32 scales without ever materializing per-client f32 vectors.
    Returns None when shapes are mixed (callers fall back to the loop)."""
    n, row = msgs[0]["n"], msgs[0]["row"]
    if any(m["n"] != n or m["row"] != row for m in msgs):
        return None
    # one decode pass, one buffer, one reshape — no per-client np arrays
    raw = b"".join(base64.b64decode(m["q"]) for m in msgs)
    q = np.frombuffer(raw, np.int8).reshape(len(msgs), -1, row)
    s = np.asarray([m["s"] for m in msgs], np.float32)
    return q, s, n, row


def aggregate_packed(
    msgs: list[dict[str, Any]], weights: np.ndarray | None = None
) -> np.ndarray:
    """FedAvg server step over packed int8 deltas via the batched path
    (`repro.fleet.compression.batched_dequant_mean`): vmap'd dequantize +
    one einsum over the client axis instead of a per-client Python loop."""
    from repro.fleet.compression import batched_dequant_mean

    stacked = stack_deltas(msgs)
    if stacked is None:  # heterogeneous shapes: per-client reference path
        return aggregate_reference(msgs, weights)
    q, s, n, _ = stacked
    return batched_dequant_mean(q, s, weights).reshape(-1)[:n]


def aggregate_reference(
    msgs: list[dict[str, Any]], weights: np.ndarray | None = None
) -> np.ndarray:
    """The pre-vectorization per-client loop, kept as the correctness
    oracle and the benchmark baseline (`benchmarks/fleet_scale.py`)."""
    deltas = [unpack_delta(m) for m in msgs]
    if weights is None:
        return np.mean(np.stack(deltas), axis=0)
    w = np.asarray(weights, np.float32)
    w = w / w.sum()
    out = np.zeros_like(deltas[0])
    for d, wi in zip(deltas, w):
        out += wi * d
    return out


#: Payload template executed inside every vehicle's task container.
#: Local data = a per-vehicle synthetic regression problem whose bias
#: comes from a *vehicle signal* (data heterogeneity driven by the fleet).
ROUND_PAYLOAD = """
import autospada
import numpy as np

p = autospada.get_parameters()
w = np.asarray(p["weights"], dtype=np.float32)
bias_sig = autospada.get_signal(p["bias_signal"])
bias = 0.0 if bias_sig is None else float(bias_sig)
rng = np.random.default_rng(int(p["data_seed"]))
X = rng.standard_normal((int(p["n_samples"]), w.shape[0])).astype(np.float32)
w_true = np.asarray(p["w_true"], dtype=np.float32) + bias
y = X @ w_true
lr = float(p["local_lr"])
w0 = w.copy()
for step in range(int(p["local_steps"])):
    g = X.T @ (X @ w - y) / X.shape[0]
    w = w - lr * g
delta = w - w0
# network-budget discipline: int8-quantize the upload
row = 256
n = delta.shape[0]
pad = (-n) % row
x = np.pad(delta, (0, pad)).reshape(-1, row)
absmax = np.maximum(np.abs(x).max(axis=1, keepdims=True), 1e-12)
s = absmax / 127.0
q = np.clip(np.round(x / s), -127, 127).astype(np.int8)
import base64
autospada.publish({
    "round": int(p["round"]),
    "q": base64.b64encode(q.tobytes()).decode(),
    "s": [float(v) for v in s[:, 0]],
    "n": int(n),
    "row": row,
    "n_samples": int(p["n_samples"]),
    "loss": float(np.mean((X @ w - y) ** 2)),
})
"""


def mean_reported_loss(msgs: list[dict[str, Any]]) -> float | None:
    """Fleet-mean of the client-reported training losses.

    A client may legitimately publish a result without a ``loss`` (legacy
    payloads, custom uploads) or with a non-finite one; those must not
    poison the round metric — ``mean(.., nan)`` turned the whole metrics
    table NaN. Missing/non-finite entries are filtered; None when no
    client reported a usable loss."""
    losses = []
    for m in msgs:
        try:
            loss = float(m["loss"])
        except (KeyError, TypeError, ValueError):
            continue
        if math.isfinite(loss):
            losses.append(loss)
    return float(np.mean(losses)) if losses else None


class FederatedDriver:
    """Runs FedAvg rounds through the platform."""

    def __init__(
        self,
        user: User,
        cfg: FedConfig,
        dim: int,
        w_true: np.ndarray,
        *,
        bias_signal: str = "Vehicle.RoadGrade",
        n_samples: int = 64,
        n_samples_fn: Callable[[int], int] | None = None,
        payload_source: str | None = None,
        engine: Any = None,
        status_oracle: bool = False,
        metrics: Any = None,
    ):
        self.user = user
        self.cfg = cfg
        #: unified event engine: round deadlines become heap entries
        self.engine = engine
        #: True = close rounds on dense statuses() scans (parity oracle)
        self.status_oracle = status_oracle
        #: FleetMetrics sink for live per-round progress gauges (fed from
        #: the same status-event counters the deadline check reads)
        self.metrics = metrics
        #: task container source; override to exercise bespoke uploads
        self.payload_source = payload_source or ROUND_PAYLOAD
        self.w = np.zeros((dim,), np.float32)
        self.w_true = w_true
        self.bias_signal = bias_signal
        self.n_samples = n_samples
        #: optional per-client dataset size (by client index within the
        #: round) — realistic fleets are data-heterogeneous, and FedAvg
        #: weights the aggregate by sample count
        self.n_samples_fn = n_samples_fn
        self.history: list[dict[str, Any]] = []
        #: raw packed deltas of the most recent round (exposed so tests can
        #: replay the aggregation against the reference loop)
        self.last_msgs: list[dict[str, Any]] = []

    def start_round(self, rnd: int, pump: Callable[[], None]) -> "RoundInFlight":
        """Commit one round's assignment and arm its deadline pump without
        pumping — the suspension point `repro.fleet.checkpoint` uses to
        snapshot a round mid-flight."""
        clients = self.user.online_clients()
        payload = self.user.payload(self.payload_source, name=f"fedavg-r{rnd}")
        tasks = []
        for i, c in enumerate(clients):
            ns = self.n_samples_fn(i) if self.n_samples_fn else self.n_samples
            params = self.user.parameter(
                {
                    "weights": [float(v) for v in self.w],
                    "w_true": [float(v) for v in self.w_true],
                    "bias_signal": self.bias_signal,
                    "data_seed": 1000 * rnd + i,
                    "n_samples": int(ns),
                    "local_lr": self.cfg.local_lr,
                    "local_steps": self.cfg.local_steps,
                    "round": rnd,
                }
            )
            tasks.append(self.user.task(c, payload, params))
        assign = self.user.assignment(f"fedavg round {rnd}", tasks).commit()

        need = max(1, int(len(clients) * self.cfg.deadline_fraction))
        on_counts = None
        if self.metrics is not None:
            self.metrics.begin_round(rnd, len(clients))
            on_counts = self.metrics.update_progress
        dpump = DeadlinePump(
            assign,
            len(clients),
            need=need,
            budget=self.cfg.deadline_pumps,
            pump=pump,
            engine=self.engine,
            status_oracle=self.status_oracle,
            on_counts=on_counts,
        )
        return RoundInFlight(
            rnd=rnd, n_clients=len(clients), assign=assign, pump=dpump
        )

    def finish_round(self, rif: "RoundInFlight") -> dict[str, Any]:
        """Pump an in-flight round to its close and aggregate."""
        rnd = rif.rnd
        assign = rif.assign
        pumps = rif.pump.run()
        # deadline reached: cancel stragglers (paper lifecycle semantics)
        canceled = assign.cancel()
        if self.metrics is not None:
            # final gauge including the deadline cancels
            self.metrics.update_progress(assign.counts())
        msgs = []
        for task_id, values in assign.results().items():
            for v in values:
                if isinstance(v, dict) and v.get("round") == rnd and "q" in v:
                    msgs.append(v)
        self.last_msgs = msgs
        weights = None
        if msgs:
            # FedAvg proper: weight each client's delta by its local sample
            # count (uploads carry n_samples; legacy results without it
            # count as 1). Uniform counts reduce to the plain mean.
            weights = np.asarray(
                [float(m.get("n_samples", 1)) for m in msgs], np.float32
            )
            # batched path: one fused dequant + weighted-sum over clients
            mean_delta = aggregate_packed(msgs, weights)
            self.w = self.w + self.cfg.server_lr * mean_delta
        rec = {
            "round": rnd,
            "participants": len(msgs),
            "canceled": canceled,
            "pumps": pumps,
            "weights": None if weights is None else [float(v) for v in weights],
            "mean_client_loss": mean_reported_loss(msgs),
            "dist_to_optimum": float(np.linalg.norm(self.w - self.w_true)),
        }
        self.history.append(rec)
        return rec

    def run_round(self, rnd: int, pump: Callable[[], None]) -> dict[str, Any]:
        return self.finish_round(self.start_round(rnd, pump))


@dataclasses.dataclass
class RoundInFlight:
    """A committed-but-not-closed FedAvg round: the assignment plus its
    armed `DeadlinePump`. Produced by `FederatedDriver.start_round`,
    consumed by `finish_round` — and by `repro.fleet.checkpoint`, which
    snapshots/restores one to checkpoint mid-round with tasks in flight."""

    rnd: int
    n_clients: int
    assign: AssignmentDoc
    pump: DeadlinePump
