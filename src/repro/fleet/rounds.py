"""Platform-driven federated rounds.

One round == one AutoSPADA assignment (DESIGN.md §2):

1. the user commits an assignment whose tasks carry the current global
   model in the Parameters document (paper §4.1: "distribute a model to
   many clients");
2. each online vehicle's task container trains locally on data derived
   from its signals and publishes a quantized delta as an ordinary result;
3. the driver awaits the deadline fraction of FINISHED tasks, cancels the
   stragglers (only ACTIVE tasks can be canceled — the lifecycle rules do
   the bookkeeping), and FedAvg-aggregates what arrived.

Deltas travel as base64-packed int8 + scales inside JSON results — the
same network-budget discipline the paper applies with protobuf/MQTT.
"""
from __future__ import annotations

import base64
import json
from typing import Any, Callable

import jax
import numpy as np

from repro.core.documents import TaskStatus
from repro.core.user import User
from repro.fleet.federated import FedConfig


# --------------------------------------------------------------------- #
# wire format: int8 delta <-> JSON-safe dict                             #
# --------------------------------------------------------------------- #
def pack_delta(flat: np.ndarray, row: int = 4096) -> dict[str, Any]:
    from repro.kernels.ref import quantize_int8_ref

    n = flat.shape[0]
    pad = (-n) % row
    x = np.pad(flat.astype(np.float32), (0, pad)).reshape(-1, row)
    q, s = quantize_int8_ref(x)
    return {
        "q": base64.b64encode(np.asarray(q, np.int8).tobytes()).decode(),
        "s": [float(v) for v in np.asarray(s)[:, 0]],
        "n": n,
        "row": row,
    }


def unpack_delta(msg: dict[str, Any]) -> np.ndarray:
    q = np.frombuffer(base64.b64decode(msg["q"]), np.int8).reshape(
        -1, msg["row"]
    )
    s = np.asarray(msg["s"], np.float32)[:, None]
    return (q.astype(np.float32) * s).reshape(-1)[: msg["n"]]


#: Payload template executed inside every vehicle's task container.
#: Local data = a per-vehicle synthetic regression problem whose bias
#: comes from a *vehicle signal* (data heterogeneity driven by the fleet).
ROUND_PAYLOAD = """
import autospada
import numpy as np

p = autospada.get_parameters()
w = np.asarray(p["weights"], dtype=np.float32)
bias_sig = autospada.get_signal(p["bias_signal"])
bias = 0.0 if bias_sig is None else float(bias_sig)
rng = np.random.default_rng(int(p["data_seed"]))
X = rng.standard_normal((int(p["n_samples"]), w.shape[0])).astype(np.float32)
w_true = np.asarray(p["w_true"], dtype=np.float32) + bias
y = X @ w_true
lr = float(p["local_lr"])
w0 = w.copy()
for step in range(int(p["local_steps"])):
    g = X.T @ (X @ w - y) / X.shape[0]
    w = w - lr * g
delta = w - w0
# network-budget discipline: int8-quantize the upload
row = 256
n = delta.shape[0]
pad = (-n) % row
x = np.pad(delta, (0, pad)).reshape(-1, row)
absmax = np.maximum(np.abs(x).max(axis=1, keepdims=True), 1e-12)
s = absmax / 127.0
q = np.clip(np.round(x / s), -127, 127).astype(np.int8)
import base64
autospada.publish({
    "round": int(p["round"]),
    "q": base64.b64encode(q.tobytes()).decode(),
    "s": [float(v) for v in s[:, 0]],
    "n": int(n),
    "row": row,
    "loss": float(np.mean((X @ w - y) ** 2)),
})
"""


class FederatedDriver:
    """Runs FedAvg rounds through the platform."""

    def __init__(
        self,
        user: User,
        cfg: FedConfig,
        dim: int,
        w_true: np.ndarray,
        *,
        bias_signal: str = "Vehicle.RoadGrade",
        n_samples: int = 64,
    ):
        self.user = user
        self.cfg = cfg
        self.w = np.zeros((dim,), np.float32)
        self.w_true = w_true
        self.bias_signal = bias_signal
        self.n_samples = n_samples
        self.history: list[dict[str, Any]] = []

    def run_round(self, rnd: int, pump: Callable[[], None]) -> dict[str, Any]:
        clients = self.user.online_clients()
        payload = self.user.payload(ROUND_PAYLOAD, name=f"fedavg-r{rnd}")
        tasks = []
        for i, c in enumerate(clients):
            params = self.user.parameter(
                {
                    "weights": [float(v) for v in self.w],
                    "w_true": [float(v) for v in self.w_true],
                    "bias_signal": self.bias_signal,
                    "data_seed": 1000 * rnd + i,
                    "n_samples": self.n_samples,
                    "local_lr": self.cfg.local_lr,
                    "local_steps": self.cfg.local_steps,
                    "round": rnd,
                }
            )
            tasks.append(self.user.task(c, payload, params))
        assign = self.user.assignment(f"fedavg round {rnd}", tasks).commit()

        need = max(1, int(len(clients) * self.cfg.deadline_fraction))
        deltas, losses = [], []
        for _ in range(100_000):
            pump()
            statuses = assign.statuses()
            done = [t for t, s in statuses.items() if s == TaskStatus.FINISHED.value]
            dead = [
                t
                for t, s in statuses.items()
                if s in (TaskStatus.ERROR.value, TaskStatus.CANCELED.value)
            ]
            if len(done) >= need or len(done) + len(dead) == len(clients):
                break
        else:  # pragma: no cover
            raise TimeoutError("round did not reach its deadline quorum")
        # deadline reached: cancel stragglers (paper lifecycle semantics)
        canceled = assign.cancel()
        for task_id, values in assign.results().items():
            for v in values:
                if isinstance(v, dict) and v.get("round") == rnd and "q" in v:
                    deltas.append(unpack_delta(v))
                    losses.append(v.get("loss", float("nan")))
        if deltas:
            mean_delta = np.mean(np.stack(deltas), axis=0)
            self.w = self.w + self.cfg.server_lr * mean_delta
        rec = {
            "round": rnd,
            "participants": len(deltas),
            "canceled": canceled,
            "mean_client_loss": float(np.mean(losses)) if losses else None,
            "dist_to_optimum": float(np.linalg.norm(self.w - self.w_true)),
        }
        self.history.append(rec)
        return rec
