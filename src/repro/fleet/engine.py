"""The unified fleet event engine (ROADMAP item 1).

One time-ordered heap drives everything the simulator used to poll for:

* **ignition toggles** — `repro.fleet.churn.EventChurn` pushes its seeded
  geometric toggle events straight into the engine (phase CHURN);
* **service wakes and straggler/resync releases** — `EngineService`
  (below) models per-client service rates as token-bucket refill events:
  an idle client's periodic dial-in is a refill at its next resync phase
  tick, and a gated straggler's budget refills at its next ungated slot
  (phase SERVICE). Broker-delivery wakes stay O(1) bit flips on a hot
  queue — no heap traffic from other threads;
* **round/analytics deadline closes** — `pump_until_deadline` registers
  the round deadline as a timer entry (phase TIMER) and closes on it.

`FleetSimulator.tick` drains the heap once per tick in O(events due):
a mostly-idle million-vehicle tick pops a handful of entries instead of
scanning the fleet. Same-tick ordering is made deterministic by the
phase number — churn toggles apply before service events, which apply
before timers — reproducing the legacy tick's phase order exactly, and
heap ties beyond (at, phase, key) break by schedule order.

Parity contract (the house rule): the dense per-tick poll survives as
the oracle — `SimConfig(backends=Backends(engine="dense", service=
"dense", churn="dense"))` runs the original O(N) loops, and the engine
must reproduce its aggregates, broker counters, and churn sequences
bit-for-bit at the same seed. `tests/test_engine.py` proves it across a
faults × churn × stragglers grid.
"""
from __future__ import annotations

import heapq
from repro.core.counter import Counter
import threading
from collections import deque
from functools import partial
from typing import TYPE_CHECKING, Callable, Iterable

import numpy as np

from repro.fleet.service import FleetServiceScheduler

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.broker import Broker, Message, Subscription
    from repro.core.client import EdgeClient
    from repro.fleet.elastic import FleetPool

#: same-tick phase order — the legacy tick applied churn toggles first,
#: then serviced clients; timers (round deadlines) observe both.
#: PHASE_ADMIT runs before everything: the fleet query gateway
#: (`repro.serve.gateway`) drains analyst requests there, so reads see
#: the between-ticks snapshot and submissions commit before this tick's
#: churn toggles or service sweep can observe them.
PHASE_ADMIT, PHASE_CHURN, PHASE_SERVICE, PHASE_TIMER = -1, 0, 1, 2


class Entry:
    """One scheduled event. `cancel()` is O(1) — the heap entry goes
    stale and is skipped on pop; `fired` flips when the drain ran it."""

    __slots__ = ("at", "phase", "key", "fn", "fired", "canceled")

    def __init__(self, at: int, phase: int, key: int, fn):
        self.at = at
        self.phase = phase
        self.key = key
        self.fn = fn
        self.fired = False
        self.canceled = False

    def cancel(self) -> None:
        self.canceled = True


class CalendarLane:
    """A numpy calendar-queue lane for phase-periodic per-index events.

    The engine heap is pure Python: at 100k+ vehicles the heappush/pop
    constant on high-rate same-tick service refills dominates a
    mostly-idle tick (the PR 6 follow-up). But the refill schedule is
    *purely periodic* — index ``i`` fires exactly when
    ``(t + i) % period == 0``, a time-invariant phase — so the whole
    schedule collapses to calendar buckets: bucket ``i % period`` holds
    index ``i`` forever, and the bucket due at tick ``t`` is
    ``(-t) % period``. Firing a tick is then ONE vectorized gather
    (`bucket[self._on[bucket]]`) over a boolean membership column instead
    of O(due) heap pops + O(due) re-pushes.

    * periodic mode (resync refills): membership = powered-on; the lane
      fires the index at every due tick while the bit is set — the exact
      fire set of a heap entry that re-arms itself one period ahead, with
      power-off handled by clearing the bit instead of a stale check.
    * ``one_shot`` mode (straggler releases): arming sets the bit; the
      first due tick clears it and fires — the next ungated slot, exactly
      the heap's ``t + (-(t + i)) % period`` booking.

    The callback receives the WHOLE due batch as one index array — the
    "batch same-tick refills into one array op" design.
    """

    __slots__ = ("period", "callback", "one_shot", "_on", "_buckets")

    def __init__(
        self,
        period: int,
        callback: Callable[[np.ndarray, int], None],
        *,
        one_shot: bool = False,
        capacity: int = 1,
    ):
        self.period = max(1, int(period))
        self.callback = callback
        self.one_shot = one_shot
        self._on = np.zeros(max(1, int(capacity)), bool)
        #: phase -> cached ascending index array (rebuilt on growth)
        self._buckets: dict[int, np.ndarray] = {}

    def ensure(self, n: int) -> None:
        if n <= len(self._on):
            return
        cap = max(int(n), 2 * len(self._on))
        arr = np.zeros(cap, bool)
        arr[: len(self._on)] = self._on
        self._on = arr
        self._buckets.clear()

    def set_member(self, i: int, member: bool) -> None:
        self.ensure(i + 1)
        self._on[i] = member

    def member(self, i: int) -> bool:
        return i < len(self._on) and bool(self._on[i])

    def _bucket(self, phase: int) -> np.ndarray:
        # cache invalidated wholesale by ensure() on growth
        b = self._buckets.get(phase)
        if b is None:
            b = np.arange(phase, len(self._on), self.period)
            self._buckets[phase] = b
        return b

    def due(self, t: int) -> np.ndarray:
        """Member indices due at tick t, ascending — one boolean gather."""
        bucket = self._bucket((-t) % self.period)
        return bucket[self._on[bucket]]

    def fire(self, t: int) -> int:
        due = self.due(t)
        if due.size == 0:
            return 0
        if self.one_shot:
            self._on[due] = False
        self.callback(due, t)
        return int(due.size)


class EventEngine:
    """A single time-ordered event heap for the whole fleet world.

    API (the registration surface the subsystems share):

    * ``schedule(at, fn)`` — run ``fn`` when the drain reaches tick
      ``at``; returns the `Entry` (cancelable, `fired`-observable).
    * ``wake(cid)`` — nudge a client's service wake hook by id (the same
      hook broker deliveries fire); O(1), callable from any thread.
    * ``on_status(topic, cb)`` — reliable subscription whose messages are
      dispatched to ``cb`` the moment they land (via `Subscription.wake`),
      not polled.

    Determinism: entries pop in ``(at, phase, key, schedule order)``
    order. All heap mutation happens on the simulator thread; cross-
    thread interaction goes through `wake`, which only touches GIL-atomic
    structures.
    """

    def __init__(self, broker: "Broker | None" = None):
        self._broker = broker
        self._heap: list[tuple[int, int, int, int, Entry]] = []
        self._seq = Counter()
        self._wakes: dict[str, Callable[[], None]] = {}
        #: calendar-queue lanes fired at the PHASE_SERVICE point of every
        #: drain (registration order). The heap stays the home of sparse
        #: timers and churn; lanes carry the high-rate periodic refills.
        self._lanes: list[CalendarLane] = []
        #: last drained tick; during a drain, the tick being drained
        self.now = 0
        #: True while `drain` runs — same-tick schedules are legal then
        self.draining = False

    # -- registration --------------------------------------------------- #
    def schedule(
        self,
        at: int,
        fn: Callable[[], None] | None = None,
        *,
        phase: int = PHASE_TIMER,
        key: int = 0,
    ) -> Entry:
        entry = Entry(int(at), phase, key, fn)
        heapq.heappush(self._heap, (entry.at, phase, key, next(self._seq), entry))
        return entry

    def bind_wake(self, cid: str, fn: Callable[[], None]) -> None:
        self._wakes[cid] = fn

    def unbind_wake(self, cid: str) -> None:
        self._wakes.pop(cid, None)

    def wake(self, cid: str) -> bool:
        """Fire a client's wake hook by id (True if one is bound)."""
        fn = self._wakes.get(cid)
        if fn is None:
            return False
        fn()
        return True

    def on_status(
        self, topic: str, cb: Callable[["Message"], None]
    ) -> "Subscription":
        """Dispatch every message on `topic` to `cb` as it is delivered.

        The subscription is reliable (user-side AMQP leg: no delay
        faults), so `cb` observes transitions synchronously with the
        store commit. Returns the subscription for unsubscribe."""
        if self._broker is None:
            raise RuntimeError("EventEngine has no broker attached")
        sub = self._broker.subscribe(topic, qos=1, reliable=True)

        def pump() -> None:
            for msg in sub.drain():
                cb(msg)

        sub.wake = pump
        return sub

    def add_lane(self, lane: CalendarLane) -> None:
        """Register a calendar-queue lane. Lanes fire between the heap's
        PHASE_CHURN and later-phase entries of each drained tick, so lane
        events occupy the same slot in the deterministic order that
        PHASE_SERVICE heap entries do (membership changes made by churn
        callbacks at tick t are visible to tick t's lane fires, exactly
        like the heap's same-drain service bookings)."""
        self._lanes.append(lane)

    # -- the per-tick sweep --------------------------------------------- #
    def drain(self, t: int) -> int:
        """Run every entry due at or before tick `t`, in deterministic
        (at, phase, key, schedule-order) order. Callbacks may schedule
        same-tick entries (e.g. a churn power-on queueing a service
        refill at `t`); the heap ordering runs them in phase order within
        this same drain. Calendar lanes fire at the churn/service
        boundary — with no lanes registered the split pop loop below is
        the original single loop, entry for entry. Returns the number of
        events fired (heap entries + lane batch members)."""
        self.now = t
        self.draining = True
        fired = 0
        heap = self._heap
        try:
            # overdue entries, this tick's gateway admissions, and this
            # tick's churn toggles first: lane membership must reflect
            # every power transition at tick t
            while heap and (
                heap[0][0] < t or (heap[0][0] == t and heap[0][1] <= PHASE_CHURN)
            ):
                entry = heapq.heappop(heap)[4]
                if entry.canceled:
                    continue
                entry.fired = True
                if entry.fn is not None:
                    entry.fn()
                fired += 1
            for lane in self._lanes:
                fired += lane.fire(t)
            while heap and heap[0][0] <= t:
                entry = heapq.heappop(heap)[4]
                if entry.canceled:
                    continue
                entry.fired = True
                if entry.fn is not None:
                    entry.fn()
                fired += 1
        finally:
            self.draining = False
        return fired

    def __len__(self) -> int:
        return len(self._heap)


class EngineService(FleetServiceScheduler):
    """Engine-native fleet service: the scheduler's sweep without the
    per-tick O(N) numpy masks.

    Where `FleetServiceScheduler` recomputes straggler/resync phase masks
    over the whole fleet every tick, this service keeps each client's
    *next* service credit in the engine heap — a token-bucket view of the
    same phase arithmetic:

    * every online client holds a **resync refill** event at its next
      ``(t + index) % resync_period == 0`` tick, rescheduled one period
      ahead each time it fires (stale-checked across power cycles);
    * a straggler that gets woken while gated books a **straggler
      release** event at its next ungated slot — its service budget
      refilling — instead of being re-examined every tick;
    * broker/container wakes append the index to a `deque` (GIL-atomic,
      any thread) and flip the runnable bit; the next tick folds the hot
      queue into the sweep.

    The sweep itself — order, gating, clear-then-set runnable discipline,
    post-advance re-arm — is the scheduler's own `_sweep`, so the parity
    argument is inherited rather than re-proven: a tick services exactly
    the indices the dense loop would touch for a broker-visible action,
    in the same ascending order.
    """

    #: events, not masks: the base class skips allocating/growing its
    #: `_idx`/`_online` per-tick gating arrays for this subclass (they
    #: were dead weight here — only the mask-based tick() reads them)
    _uses_masks = False

    def __init__(
        self,
        engine: EventEngine,
        pool: "FleetPool",
        *,
        steps_per_tick: int,
        resync_period: int,
        straggler_period: int,
        straggler_indices: Iterable[int] = (),
    ):
        self._engine = engine
        self._hot: deque[int] = deque()
        self._due: list[int] = []
        self._resync_at: dict[int, int] = {}
        self._release_at: dict[int, int] = {}
        super().__init__(
            pool,
            steps_per_tick=steps_per_tick,
            resync_period=resync_period,
            straggler_period=straggler_period,
            straggler_indices=straggler_indices,
        )

    # -- wake plumbing --------------------------------------------------- #
    def _make_wake(self, i: int):
        def wake() -> None:
            live = self._live
            if (
                live is not None
                and threading.current_thread() is self._sweep_thread
            ):
                if i == self._cursor:
                    # self-wake of the client being serviced: the sweep's
                    # post-advance has_work check decides runnability
                    return
                if not self._runnable[i]:
                    self._runnable[i] = True
                    self._hot.append(i)
                if i > self._cursor:
                    heapq.heappush(live, i)
                return
            # outside a sweep / from another thread: flip the bit and note
            # the index on the hot queue — there is no per-tick mask to
            # pick a lone bit up, so the flip must leave a trace
            if not self._runnable[i]:
                self._runnable[i] = True
                self._hot.append(i)

        return wake

    def _note_runnable(self, i: int) -> None:
        # post-advance re-arm: still has work => service again next tick
        self._runnable[i] = True
        self._hot.append(i)

    # -- token-bucket refill events -------------------------------------- #
    def _schedule_resync(self, i: int) -> None:
        eng = self._engine
        # earliest serviceable tick: the tick being drained if we are
        # inside a drain (a churn power-on), else the next one
        t0 = eng.now + (0 if eng.draining else 1)
        at = t0 + (-(t0 + i)) % self.resync_period
        self._resync_at[i] = at
        eng.schedule(
            at, partial(self._fire_resync, i, at), phase=PHASE_SERVICE, key=i
        )

    def _fire_resync(self, i: int, at: int) -> None:
        if self._resync_at.get(i) != at:
            return  # stale: the client power-cycled since this was booked
        nxt = at + self.resync_period
        self._resync_at[i] = nxt
        self._engine.schedule(
            nxt, partial(self._fire_resync, i, nxt), phase=PHASE_SERVICE, key=i
        )
        self._due.append(i)

    def _on_gated_skip(self, i: int, t: int) -> None:
        # a straggler woke while gated: book its budget refill at the next
        # ungated slot instead of re-checking the gate every tick
        if not self._runnable[i] or i in self._release_at:
            return
        at = t + (-(t + i)) % self.straggler_period
        self._release_at[i] = at
        self._engine.schedule(
            at, partial(self._fire_release, i, at), phase=PHASE_SERVICE, key=i
        )

    def _fire_release(self, i: int, at: int) -> None:
        if self._release_at.get(i) != at:
            return
        del self._release_at[i]
        if self._runnable[i] and self._clients[i] is not None:
            self._due.append(i)

    # -- pool membership hooks -------------------------------------------- #
    def client_powered_on(self, index: int, client: "EdgeClient") -> None:
        super().client_powered_on(index, client)
        if self._runnable[index]:
            self._hot.append(index)
        self._engine.bind_wake(client.client_id, self._make_wake(index))
        self._schedule_resync(index)

    def client_powered_off(self, index: int) -> None:
        if index < self._capacity:
            c = self._clients[index]
            if c is not None:
                self._engine.unbind_wake(c.client_id)
        super().client_powered_off(index)
        # pending refill events go stale rather than being heap-deleted
        self._resync_at.pop(index, None)
        self._release_at.pop(index, None)

    # -- the per-tick service step ---------------------------------------- #
    def tick(self, t: int) -> None:
        """Service exactly the clients with a due event this tick: refill
        events collected by the engine drain plus hot-queue wakes — no
        fleet-wide mask, O(due + runnable)."""
        live = self._due
        self._due = []
        hot = self._hot
        while hot:
            i = hot.popleft()
            if self._runnable[i] and self._clients[i] is not None:
                live.append(i)
        heapq.heapify(live)
        self._sweep(live, t)


class CalendarService(EngineService):
    """`EngineService` with the periodic refill schedule moved out of the
    Python heap into numpy `CalendarLane`s — the 100k+ fast path.

    The heap version books one entry per online client per resync period
    and re-pushes it on every fire: O(online) heappush/pop per period,
    pure Python, the dominant cost of a mostly-idle mega-fleet tick. But
    the resync schedule is time-invariant — client ``i`` refills exactly
    when ``(t + i) % resync_period == 0`` — so membership in calendar
    bucket ``i % period`` plus a powered-on bit reproduces the heap's
    fire set with ONE vectorized gather per tick:

    * **resync lane** (periodic): the membership bit IS the power state;
      `_schedule_resync`/power-off set/clear it. No re-arming, no stale
      checks — a cleared bit is the stale check.
    * **release lane** (one-shot): `_on_gated_skip` arms the bit; the
      lane fires it at the next ungated slot — the heap's
      ``t + (-(t + i)) % straggler_period`` booking — and clears it.

    Everything else — the hot wake queue, `_due` consumption, `_sweep`
    order and discipline — is inherited unchanged, so the bit-for-bit
    parity argument reduces to lane-fires == heap-fires, which
    `tests/test_calendar.py` proves over random schedules and a dense
    grid. The heap `EngineService` stays the parity oracle per house
    style.
    """

    def __init__(
        self,
        engine: EventEngine,
        pool: "FleetPool",
        *,
        steps_per_tick: int,
        resync_period: int,
        straggler_period: int,
        straggler_indices: Iterable[int] = (),
    ):
        cap = max(1, len(pool.vehicles))
        self._resync_lane = CalendarLane(
            resync_period, self._lane_resync, capacity=cap
        )
        self._release_lane = CalendarLane(
            straggler_period, self._lane_release, one_shot=True, capacity=cap
        )
        engine.add_lane(self._resync_lane)
        engine.add_lane(self._release_lane)
        # super() powers on every live vehicle, which routes through the
        # lane-backed _schedule_resync below — lanes must already exist
        super().__init__(
            engine,
            pool,
            steps_per_tick=steps_per_tick,
            resync_period=resync_period,
            straggler_period=straggler_period,
            straggler_indices=straggler_indices,
        )

    # -- lane callbacks (whole due batch per tick) ----------------------- #
    def _lane_resync(self, idx: "np.ndarray", t: int) -> None:
        # the heap's _fire_resync appended unconditionally (staleness was
        # the power check); membership already encodes power state here
        self._due.extend(idx.tolist())

    def _lane_release(self, idx: "np.ndarray", t: int) -> None:
        runnable = self._runnable
        clients = self._clients
        for i in idx.tolist():
            if runnable[i] and clients[i] is not None:
                self._due.append(i)

    # -- lane-backed refill schedule -------------------------------------- #
    def _schedule_resync(self, i: int) -> None:
        self._resync_lane.set_member(i, True)

    def _on_gated_skip(self, i: int, t: int) -> None:
        if not self._runnable[i] or self._release_lane.member(i):
            return
        self._release_lane.set_member(i, True)

    def client_powered_off(self, index: int) -> None:
        super().client_powered_off(index)
        self._resync_lane.set_member(index, False)
        self._release_lane.set_member(index, False)
