"""The unified fleet event engine (ROADMAP item 1).

One time-ordered heap drives everything the simulator used to poll for:

* **ignition toggles** — `repro.fleet.churn.EventChurn` pushes its seeded
  geometric toggle events straight into the engine (phase CHURN);
* **service wakes and straggler/resync releases** — `EngineService`
  (below) models per-client service rates as token-bucket refill events:
  an idle client's periodic dial-in is a refill at its next resync phase
  tick, and a gated straggler's budget refills at its next ungated slot
  (phase SERVICE). Broker-delivery wakes stay O(1) bit flips on a hot
  queue — no heap traffic from other threads;
* **round/analytics deadline closes** — `pump_until_deadline` registers
  the round deadline as a timer entry (phase TIMER) and closes on it.

`FleetSimulator.tick` drains the heap once per tick in O(events due):
a mostly-idle million-vehicle tick pops a handful of entries instead of
scanning the fleet. Same-tick ordering is made deterministic by the
phase number — churn toggles apply before service events, which apply
before timers — reproducing the legacy tick's phase order exactly, and
heap ties beyond (at, phase, key) break by schedule order.

Parity contract (the house rule): the dense per-tick poll survives as
the oracle — `SimConfig(backends=Backends(engine="dense", service=
"dense", churn="dense"))` runs the original O(N) loops, and the engine
must reproduce its aggregates, broker counters, and churn sequences
bit-for-bit at the same seed. `tests/test_engine.py` proves it across a
faults × churn × stragglers grid.
"""
from __future__ import annotations

import heapq
from repro.core.counter import Counter
import threading
from collections import deque
from functools import partial
from typing import TYPE_CHECKING, Callable, Iterable

from repro.fleet.service import FleetServiceScheduler

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.broker import Broker, Message, Subscription
    from repro.core.client import EdgeClient
    from repro.fleet.elastic import FleetPool

#: same-tick phase order — the legacy tick applied churn toggles first,
#: then serviced clients; timers (round deadlines) observe both
PHASE_CHURN, PHASE_SERVICE, PHASE_TIMER = 0, 1, 2


class Entry:
    """One scheduled event. `cancel()` is O(1) — the heap entry goes
    stale and is skipped on pop; `fired` flips when the drain ran it."""

    __slots__ = ("at", "phase", "key", "fn", "fired", "canceled")

    def __init__(self, at: int, phase: int, key: int, fn):
        self.at = at
        self.phase = phase
        self.key = key
        self.fn = fn
        self.fired = False
        self.canceled = False

    def cancel(self) -> None:
        self.canceled = True


class EventEngine:
    """A single time-ordered event heap for the whole fleet world.

    API (the registration surface the subsystems share):

    * ``schedule(at, fn)`` — run ``fn`` when the drain reaches tick
      ``at``; returns the `Entry` (cancelable, `fired`-observable).
    * ``wake(cid)`` — nudge a client's service wake hook by id (the same
      hook broker deliveries fire); O(1), callable from any thread.
    * ``on_status(topic, cb)`` — reliable subscription whose messages are
      dispatched to ``cb`` the moment they land (via `Subscription.wake`),
      not polled.

    Determinism: entries pop in ``(at, phase, key, schedule order)``
    order. All heap mutation happens on the simulator thread; cross-
    thread interaction goes through `wake`, which only touches GIL-atomic
    structures.
    """

    def __init__(self, broker: "Broker | None" = None):
        self._broker = broker
        self._heap: list[tuple[int, int, int, int, Entry]] = []
        self._seq = Counter()
        self._wakes: dict[str, Callable[[], None]] = {}
        #: last drained tick; during a drain, the tick being drained
        self.now = 0
        #: True while `drain` runs — same-tick schedules are legal then
        self.draining = False

    # -- registration --------------------------------------------------- #
    def schedule(
        self,
        at: int,
        fn: Callable[[], None] | None = None,
        *,
        phase: int = PHASE_TIMER,
        key: int = 0,
    ) -> Entry:
        entry = Entry(int(at), phase, key, fn)
        heapq.heappush(self._heap, (entry.at, phase, key, next(self._seq), entry))
        return entry

    def bind_wake(self, cid: str, fn: Callable[[], None]) -> None:
        self._wakes[cid] = fn

    def unbind_wake(self, cid: str) -> None:
        self._wakes.pop(cid, None)

    def wake(self, cid: str) -> bool:
        """Fire a client's wake hook by id (True if one is bound)."""
        fn = self._wakes.get(cid)
        if fn is None:
            return False
        fn()
        return True

    def on_status(
        self, topic: str, cb: Callable[["Message"], None]
    ) -> "Subscription":
        """Dispatch every message on `topic` to `cb` as it is delivered.

        The subscription is reliable (user-side AMQP leg: no delay
        faults), so `cb` observes transitions synchronously with the
        store commit. Returns the subscription for unsubscribe."""
        if self._broker is None:
            raise RuntimeError("EventEngine has no broker attached")
        sub = self._broker.subscribe(topic, qos=1, reliable=True)

        def pump() -> None:
            for msg in sub.drain():
                cb(msg)

        sub.wake = pump
        return sub

    # -- the per-tick sweep --------------------------------------------- #
    def drain(self, t: int) -> int:
        """Run every entry due at or before tick `t`, in deterministic
        (at, phase, key, schedule-order) order. Callbacks may schedule
        same-tick entries (e.g. a churn power-on queueing a service
        refill at `t`); the heap ordering runs them in phase order within
        this same drain. Returns the number of entries fired."""
        self.now = t
        self.draining = True
        fired = 0
        heap = self._heap
        try:
            while heap and heap[0][0] <= t:
                entry = heapq.heappop(heap)[4]
                if entry.canceled:
                    continue
                entry.fired = True
                if entry.fn is not None:
                    entry.fn()
                fired += 1
        finally:
            self.draining = False
        return fired

    def __len__(self) -> int:
        return len(self._heap)


class EngineService(FleetServiceScheduler):
    """Engine-native fleet service: the scheduler's sweep without the
    per-tick O(N) numpy masks.

    Where `FleetServiceScheduler` recomputes straggler/resync phase masks
    over the whole fleet every tick, this service keeps each client's
    *next* service credit in the engine heap — a token-bucket view of the
    same phase arithmetic:

    * every online client holds a **resync refill** event at its next
      ``(t + index) % resync_period == 0`` tick, rescheduled one period
      ahead each time it fires (stale-checked across power cycles);
    * a straggler that gets woken while gated books a **straggler
      release** event at its next ungated slot — its service budget
      refilling — instead of being re-examined every tick;
    * broker/container wakes append the index to a `deque` (GIL-atomic,
      any thread) and flip the runnable bit; the next tick folds the hot
      queue into the sweep.

    The sweep itself — order, gating, clear-then-set runnable discipline,
    post-advance re-arm — is the scheduler's own `_sweep`, so the parity
    argument is inherited rather than re-proven: a tick services exactly
    the indices the dense loop would touch for a broker-visible action,
    in the same ascending order.
    """

    #: events, not masks: the base class skips allocating/growing its
    #: `_idx`/`_online` per-tick gating arrays for this subclass (they
    #: were dead weight here — only the mask-based tick() reads them)
    _uses_masks = False

    def __init__(
        self,
        engine: EventEngine,
        pool: "FleetPool",
        *,
        steps_per_tick: int,
        resync_period: int,
        straggler_period: int,
        straggler_indices: Iterable[int] = (),
    ):
        self._engine = engine
        self._hot: deque[int] = deque()
        self._due: list[int] = []
        self._resync_at: dict[int, int] = {}
        self._release_at: dict[int, int] = {}
        super().__init__(
            pool,
            steps_per_tick=steps_per_tick,
            resync_period=resync_period,
            straggler_period=straggler_period,
            straggler_indices=straggler_indices,
        )

    # -- wake plumbing --------------------------------------------------- #
    def _make_wake(self, i: int):
        def wake() -> None:
            live = self._live
            if (
                live is not None
                and threading.current_thread() is self._sweep_thread
            ):
                if i == self._cursor:
                    # self-wake of the client being serviced: the sweep's
                    # post-advance has_work check decides runnability
                    return
                if not self._runnable[i]:
                    self._runnable[i] = True
                    self._hot.append(i)
                if i > self._cursor:
                    heapq.heappush(live, i)
                return
            # outside a sweep / from another thread: flip the bit and note
            # the index on the hot queue — there is no per-tick mask to
            # pick a lone bit up, so the flip must leave a trace
            if not self._runnable[i]:
                self._runnable[i] = True
                self._hot.append(i)

        return wake

    def _note_runnable(self, i: int) -> None:
        # post-advance re-arm: still has work => service again next tick
        self._runnable[i] = True
        self._hot.append(i)

    # -- token-bucket refill events -------------------------------------- #
    def _schedule_resync(self, i: int) -> None:
        eng = self._engine
        # earliest serviceable tick: the tick being drained if we are
        # inside a drain (a churn power-on), else the next one
        t0 = eng.now + (0 if eng.draining else 1)
        at = t0 + (-(t0 + i)) % self.resync_period
        self._resync_at[i] = at
        eng.schedule(
            at, partial(self._fire_resync, i, at), phase=PHASE_SERVICE, key=i
        )

    def _fire_resync(self, i: int, at: int) -> None:
        if self._resync_at.get(i) != at:
            return  # stale: the client power-cycled since this was booked
        nxt = at + self.resync_period
        self._resync_at[i] = nxt
        self._engine.schedule(
            nxt, partial(self._fire_resync, i, nxt), phase=PHASE_SERVICE, key=i
        )
        self._due.append(i)

    def _on_gated_skip(self, i: int, t: int) -> None:
        # a straggler woke while gated: book its budget refill at the next
        # ungated slot instead of re-checking the gate every tick
        if not self._runnable[i] or i in self._release_at:
            return
        at = t + (-(t + i)) % self.straggler_period
        self._release_at[i] = at
        self._engine.schedule(
            at, partial(self._fire_release, i, at), phase=PHASE_SERVICE, key=i
        )

    def _fire_release(self, i: int, at: int) -> None:
        if self._release_at.get(i) != at:
            return
        del self._release_at[i]
        if self._runnable[i] and self._clients[i] is not None:
            self._due.append(i)

    # -- pool membership hooks -------------------------------------------- #
    def client_powered_on(self, index: int, client: "EdgeClient") -> None:
        super().client_powered_on(index, client)
        if self._runnable[index]:
            self._hot.append(index)
        self._engine.bind_wake(client.client_id, self._make_wake(index))
        self._schedule_resync(index)

    def client_powered_off(self, index: int) -> None:
        if index < self._capacity:
            c = self._clients[index]
            if c is not None:
                self._engine.unbind_wake(c.client_id)
        super().client_powered_off(index)
        # pending refill events go stale rather than being heap-deleted
        self._resync_at.pop(index, None)
        self._release_at.pop(index, None)

    # -- the per-tick service step ---------------------------------------- #
    def tick(self, t: int) -> None:
        """Service exactly the clients with a due event this tick: refill
        events collected by the engine drain plus hot-queue wakes — no
        fleet-wide mask, O(due + runnable)."""
        live = self._due
        self._due = []
        hot = self._hot
        while hot:
            i = hot.popleft()
            if self._runnable[i] and self._clients[i] is not None:
                live.append(i)
        heapq.heapify(live)
        self._sweep(live, t)
