"""Seeded drive-cycle scenario generators for the fleet signal plane.

The paper's operational case study is streaming statistics over
fuel-consumption signals from real driving; the old simulator fed every
vehicle a hand-rolled ``constant(0.01 * (i % 7))`` road-grade iterator.
These generators produce physically-flavoured, *seeded* signal streams for
the whole fleet at once — each scenario is a pure function
``(seed, client, t) -> signals`` evaluated as one jit step over the
``(n_clients, n_signals)`` plane per tick:

* ``highway``    — cruise near a per-vehicle set speed with slow speed and
                   road-grade oscillation;
* ``urban``      — stop-go duty cycles: accelerate, brake, idle at lights;
* ``idle``       — cold idle: stationary, warming engine, idle fuel burn;
* ``mixed``      — every vehicle seeded into one of the above regimes
                   (the realistic fleet default for analytics);
* ``road-grade`` — the legacy constant per-vehicle grade (exactly
                   ``0.01 * (i % 7)``), time-invariant: the simulator's
                   default, preserving the fault-free == lossy aggregate
                   property that the resiliency tests pin down.

Determinism and row stability: per-client randomness is derived with
``fold_in(key(seed), client_index)`` and per-tick noise with a further
``fold_in(·, t)``, so the same (seed, i) yields the same stream at any
fleet size — a vehicle joining mid-experiment never perturbs existing
rows (`FleetSignalPlane.add_client` relies on this).

`scripted_brokers` renders the same streams through the legacy
per-vehicle `ScriptedSignalBroker` path; the parity tests prove the two
pipelines are payload-indistinguishable.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.signals import FleetSignalPlane, ScriptedSignalBroker

#: canonical signal names every scenario publishes, column order fixed
SIGNALS: tuple[str, ...] = (
    "Vehicle.Speed",          # km/h
    "Vehicle.FuelRate",       # L/h
    "Vehicle.RoadGrade",      # dimensionless slope
    "Engine.Temperature",     # deg C
)

_HIGHWAY, _URBAN, _IDLE = 0, 1, 2

#: regime mix of the ``mixed`` fleet
_MIX = (0.45, 0.35, 0.20)

SCENARIOS = ("road-grade", "highway", "urban", "idle", "mixed")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, seeded drive-cycle family. `step_fn(n)` is the scenario's
    pure jax step — a traceable `t -> (n, len(SIGNALS))` float32 matrix —
    shared verbatim by both plane implementations, so the single-host and
    the sharded plane are bit-for-bit identical by construction.
    `series(n)` wraps it for the host plane (jit + numpy)."""

    name: str
    seed: int = 0
    signals: tuple[str, ...] = SIGNALS

    def step_fn(self, n_clients: int) -> Callable[[jax.Array], jax.Array]:
        if self.name == "road-grade":
            return _constant_road_grade_step(n_clients)
        if self.name not in SCENARIOS:
            raise ValueError(
                f"unknown scenario {self.name!r}; pick one of {SCENARIOS}"
            )
        return _drive_cycle_step(self.name, n_clients, self.seed)

    def series(self, n_clients: int) -> Callable[[int], np.ndarray]:
        if self.name == "road-grade":
            # host fast path: the step is constant in t, so the hot tick
            # returns one cached numpy array — no jit dispatch, no
            # device->host copy (same bits as the sharded step, which
            # jnp.asarrays this very array)
            vals = _constant_road_grade_values(n_clients)
            return lambda t: vals
        step = jax.jit(self.step_fn(n_clients))

        def series(t: int) -> np.ndarray:
            return np.asarray(step(jnp.int32(t)))

        return series

    def plane(self, n_clients: int, *, history: int = 256) -> FleetSignalPlane:
        return FleetSignalPlane(
            self.signals,
            self.series(n_clients),
            history=history,
            grow_fn=self.series,
        )

    def sharded_plane(
        self, n_clients: int, *, history: int = 256, mesh=None
    ) -> "ShardedSignalPlane":
        """The same scenario over a device-sharded plane: the per-tick
        step is jit'd once with in/out shardings over a client-axis mesh
        (`repro.sharding.fleet`), so each device advances only its rows."""
        from repro.core.plane_sharded import ShardedSignalPlane

        return ShardedSignalPlane(
            self.signals,
            n_clients,
            self.step_fn,
            history=history,
            mesh=mesh,
        )


#: plane implementations `build_plane` can select
PLANES = ("host", "sharded")


def build_plane(
    name: str,
    n_clients: int,
    seed: int = 0,
    *,
    history: int = 256,
    plane: str = "host",
    mesh=None,
) -> FleetSignalPlane:
    """The one-liner the simulator uses. ``plane`` picks the single-host
    columnar plane (default) or the device-sharded plane."""
    scen = Scenario(name, seed)
    if plane == "host":
        return scen.plane(n_clients, history=history)
    if plane == "sharded":
        return scen.sharded_plane(n_clients, history=history, mesh=mesh)
    raise ValueError(f"unknown plane {plane!r}; pick one of {PLANES}")


# --------------------------------------------------------------------- #
# the legacy constant default                                            #
# --------------------------------------------------------------------- #
def _constant_road_grade_values(n: int) -> np.ndarray:
    """Time-invariant per-vehicle signals; `Vehicle.RoadGrade` reproduces
    the historical ``constant(0.01 * (i % 7))`` exactly. Constant in t so
    runs whose rounds consume different tick counts (lossy vs fault-free)
    still see identical payload inputs."""
    i = np.arange(n, dtype=np.float32)
    grade = np.float32(0.01) * (i % np.float32(7))
    speed = np.full(n, 80.0, np.float32)
    fuel = (0.6 + 0.04 * speed + 60.0 * np.maximum(grade, 0.0)).astype(np.float32)
    temp = np.full(n, 90.0, np.float32)
    return np.stack([speed, fuel, grade, temp], axis=1).astype(np.float32)


def _constant_road_grade_step(n: int) -> Callable[[jax.Array], jax.Array]:
    vals = _constant_road_grade_values(n)

    def step(t: jax.Array) -> jax.Array:
        return jnp.asarray(vals)

    return step


# --------------------------------------------------------------------- #
# drive cycles: one pure step for the whole fleet                        #
# --------------------------------------------------------------------- #
def _drive_cycle_step(
    name: str, n: int, seed: int
) -> Callable[[jax.Array], jax.Array]:
    """The scenario's pure per-tick function, `t -> (n, n_signals)` f32.

    Everything — per-client keys included — is computed *inside* the
    returned function from the scalar seed, so the function carries no
    captured device buffers: the host plane jits it plain, the sharded
    plane jits the identical function with client-axis in/out shardings
    (every op is elementwise per row, so partitioning inserts no
    collectives), and the two evaluate bit-for-bit the same."""

    def step(t: jax.Array) -> jax.Array:
        base = jax.random.PRNGKey(seed)
        idx = jnp.arange(n, dtype=jnp.uint32)
        ckeys = jax.vmap(lambda i: jax.random.fold_in(base, i))(idx)
        u = jax.vmap(lambda k: jax.random.uniform(k, (6,)))(ckeys)  # (n, 6)

        if name == "mixed":
            c0, c1 = _MIX[0], _MIX[0] + _MIX[1]
            regime = jnp.where(
                u[:, 0] < c0, _HIGHWAY, jnp.where(u[:, 0] < c1, _URBAN, _IDLE)
            )
        else:
            regime = jnp.full(
                (n,),
                {"highway": _HIGHWAY, "urban": _URBAN, "idle": _IDLE}[name],
                jnp.int32,
            )

        cruise = 95.0 + 25.0 * u[:, 1]        # highway set speed, km/h
        peak = 28.0 + 24.0 * u[:, 1]          # urban peak between stops
        hw_period = 40.0 + 40.0 * u[:, 2]     # highway oscillation, ticks
        ub_period = 8.0 + 10.0 * u[:, 2]      # urban stop-go cycle, ticks
        phase = 2.0 * jnp.pi * u[:, 3]
        grade0 = 0.06 * (u[:, 4] - 0.5)
        noise = 0.3 + 0.7 * u[:, 5]

        tf = t.astype(jnp.float32)
        tkeys = jax.vmap(lambda k: jax.random.fold_in(k, t))(ckeys)
        eps = jax.vmap(lambda k: jax.random.normal(k, (2,)))(tkeys)  # (n, 2)

        # highway: cruise + slow sinusoid + noise
        v_hw = cruise + 8.0 * jnp.sin(2.0 * jnp.pi * tf / hw_period + phase)
        v_hw = v_hw + noise * eps[:, 0]
        # urban: duty cycle — moving 60% of the cycle, stopped at "lights"
        frac = jnp.mod(tf / ub_period + phase / (2.0 * jnp.pi), 1.0)
        moving = frac < 0.6
        v_ub = jnp.where(
            moving,
            peak * jnp.sin(jnp.pi * frac / 0.6) + 0.5 * noise * eps[:, 0],
            0.0,
        )
        speed = jnp.select(
            [regime == _HIGHWAY, regime == _URBAN], [v_hw, v_ub], 0.0
        )
        speed = jnp.maximum(speed, 0.0)

        grade_osc = 0.02 * jnp.sin(2.0 * jnp.pi * tf / (3.0 * hw_period) + 2.0 * phase)
        grade = jnp.where(regime == _IDLE, 0.0, grade0 + grade_osc)

        # fuel rate: idle burn + speed term + uphill load + combustion noise
        fuel = (
            0.6
            + 0.04 * speed
            + 1.2 * jnp.maximum(grade, 0.0) * speed
            + 0.05 * noise * eps[:, 1]
        )
        fuel = jnp.maximum(fuel, 0.15)

        # engine warmup toward the regime's steady temperature
        ambient = jnp.where(regime == _IDLE, -5.0, 15.0)
        target = jnp.where(regime == _IDLE, 55.0, 90.0)
        tau = jnp.where(regime == _IDLE, 120.0, 40.0)
        temp = ambient + (target - ambient) * (1.0 - jnp.exp(-tf / tau))

        return jnp.stack([speed, fuel, grade, temp], axis=1).astype(jnp.float32)

    return step


# --------------------------------------------------------------------- #
# legacy-path adapters (parity testing, per-vehicle scripting)           #
# --------------------------------------------------------------------- #
def scenario_trace(
    scenario: Scenario, n_clients: int, n_ticks: int
) -> np.ndarray:
    """Materialize `(n_ticks, n_clients, n_signals)` of the scenario —
    tick 0 is the plane's initial state."""
    series = scenario.series(n_clients)
    return np.stack([series(t) for t in range(n_ticks)], axis=0)


def scripted_brokers(
    scenario: Scenario, n_clients: int, n_ticks: int
) -> list[ScriptedSignalBroker]:
    """The same streams through the legacy per-vehicle iterator path.
    Broker i's iterator for signal j yields the identical float32 values
    the plane's row i column j takes at ticks 0..n_ticks-1 (then holds)."""
    trace = scenario_trace(scenario, n_clients, n_ticks)
    return [
        ScriptedSignalBroker(
            {
                name: iter([float(v) for v in trace[:, i, j]])
                for j, name in enumerate(scenario.signals)
            }
        )
        for i in range(n_clients)
    ]
