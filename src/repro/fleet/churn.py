"""Event-driven ignition churn: geometric inter-arrival times per vehicle.

The simulator's churn step used to draw one RNG sample *per vehicle per
tick* whenever ``p_leave``/``p_return`` were set — the last O(N) Python
loop on the tick path (ROADMAP). A per-tick Bernoulli(p) coin is
equivalent to drawing the whole waiting time at once: the number of ticks
until the first success is Geometric(p), sampled in O(1) by inverse CDF
(``1 + floor(log1p(-u) / log1p(-p))``). So each vehicle gets a seeded
*event time* instead of a nightly coin, and a tick costs O(events), not
O(N):

* `EventChurn` — a min-heap of ``(tick, index, cid)`` toggle events.
  ``pop_due(now)`` pops only vehicles whose ignition flips this tick.
* `DenseChurn` — the O(N)-scan oracle: same per-vehicle RNG streams, same
  scheduling rule, but ``pop_due`` walks every watched vehicle. The
  parity test proves the heap machinery reproduces the dense scan's
  toggle sequence exactly at a fixed seed.

Determinism and composability: every vehicle draws from its own
``default_rng((seed, 0xC0FFEE, index))`` stream, so event times never
depend on fleet size, membership order, or how other vehicles toggle —
the same row-stability contract the signal scenarios follow. External
power transitions (tests and drivers call `FleetPool.power_on/off`
directly) re-enter through `notify`, which reschedules the vehicle from
its *actual* new state, so the schedule can never disagree with the
world: an externally parked vehicle still returns at a Geometric
(p_return) horizon, exactly like the per-tick coin did.
"""
from __future__ import annotations

import heapq
import math
from functools import partial

import numpy as np


def geometric_gap(u: float, p: float) -> int:
    """Ticks until the first success of a Bernoulli(p) sequence (>= 1),
    from one uniform draw: the inverse-CDF geometric sample."""
    if p >= 1.0:
        return 1
    return 1 + int(math.floor(math.log1p(-u) / math.log1p(-p)))


class EventChurn:
    """Seeded churn event schedule, O(events) per tick.

    A watched *online* vehicle holds a pending ignition-off event at a
    Geometric(p_leave) horizon; an *offline* one holds an ignition-on
    event at Geometric(p_return). A probability of zero means that
    transition never fires (matching the per-tick coin, which could never
    land below 0). `pop_due` yields the cids whose toggle is due this
    tick, in fleet (index) order — the order the dense per-vehicle loop
    used.
    """

    def __init__(self, seed: int, p_leave: float, p_return: float):
        self.p_leave = float(p_leave)
        self.p_return = float(p_return)
        self._seed = seed
        self._rng: dict[str, np.random.Generator] = {}
        self._online: dict[str, bool] = {}
        self._index: dict[str, int] = {}
        self._next: dict[str, int | None] = {}
        self._heap: list[tuple[int, int, str]] = []
        self.now = 0
        #: when attached, toggle events live in the unified engine heap
        #: (repro.fleet.engine) instead of the private one
        self._engine = None
        self._toggle = None

    # -- unified-engine sink --------------------------------------------- #
    def attach_engine(self, engine, toggle) -> None:
        """Route future (and any already-pending) toggle events into the
        unified `EventEngine` heap. `toggle(cid)` performs the power
        transition; `pop_due` is never called in this mode — the engine's
        drain fires toggles in the same (tick, index) fleet order."""
        from repro.fleet.engine import PHASE_CHURN  # cycle-free late import

        self._engine = engine
        self._toggle = toggle
        self._phase = PHASE_CHURN
        while self._heap:
            t, idx, cid = heapq.heappop(self._heap)
            if self._next.get(cid) == t:
                engine.schedule(
                    t, partial(self._fire, cid, t), phase=PHASE_CHURN, key=idx
                )

    def _fire(self, cid: str, t: int) -> None:
        if self._next.get(cid) != t:
            return  # stale: rescheduled or canceled since pushed
        self.now = max(self.now, t)
        self._next[cid] = None
        self._toggle(cid)  # re-enters via notify to draw the next gap

    # -- membership ------------------------------------------------------ #
    def watch(self, cid: str, index: int, online: bool, now: int | None = None) -> None:
        """Start scheduling a vehicle. Idempotent per cid."""
        if cid in self._online:
            return
        if now is not None:
            self.now = max(self.now, now)
        self._index[cid] = int(index)
        self._rng[cid] = np.random.default_rng((self._seed, 0xC0FFEE, int(index)))
        self._online[cid] = bool(online)
        self._next[cid] = None
        self._schedule(cid)

    def notify(self, cid: str, index: int, online: bool) -> None:
        """A power transition happened (churn-driven or external): track
        the new state and reschedule from it. Unknown vehicles (joined
        mid-experiment) are auto-watched."""
        if cid not in self._online:
            self.watch(cid, index, online)
            return
        if self._online[cid] == bool(online):
            return
        self._online[cid] = bool(online)
        self._schedule(cid)

    # -- scheduling ------------------------------------------------------ #
    #: DenseChurn never drains the heap, so it must not feed it either
    _use_heap = True

    def _schedule(self, cid: str) -> None:
        if self._engine is not None:
            # external transitions between ticks draw from the engine's
            # clock (the legacy path refreshed `now` in every pop_due)
            self.now = max(self.now, self._engine.now)
        p = self.p_leave if self._online[cid] else self.p_return
        if p <= 0.0:
            self._next[cid] = None  # pending heap entries become stale
            return
        t = self.now + geometric_gap(float(self._rng[cid].random()), p)
        self._next[cid] = t
        if self._engine is not None:
            self._engine.schedule(
                t, partial(self._fire, cid, t), phase=self._phase,
                key=self._index[cid],
            )
        elif self._use_heap:
            heapq.heappush(self._heap, (t, self._index[cid], cid))

    def pop_due(self, now: int) -> list[str]:
        """Vehicles whose ignition toggles at `now`, in fleet order.
        The caller performs the actual power transition, whose `notify`
        re-enters to schedule the next event from the new state."""
        self.now = now
        due: list[str] = []
        while self._heap and self._heap[0][0] <= now:
            t, _, cid = heapq.heappop(self._heap)
            if self._next.get(cid) != t:
                continue  # stale: rescheduled or canceled since pushed
            self._next[cid] = None
            due.append(cid)
        return due


class DenseChurn(EventChurn):
    """The O(N) oracle: identical streams and scheduling rule, but each
    tick scans every watched vehicle for a due event — the shape of the
    old per-vehicle per-tick loop. Exists to pin the heap's behaviour."""

    _use_heap = False  # the scan reads _next only; don't grow the heap

    def pop_due(self, now: int) -> list[str]:
        self.now = now
        due = [
            cid
            for cid, t in sorted(
                self._next.items(), key=lambda kv: self._index[kv[0]]
            )
            if t is not None and t <= now
        ]
        for cid in due:
            self._next[cid] = None
        return due


CHURNS = ("event", "dense")


def make_churn(kind: str, seed: int, p_leave: float, p_return: float) -> EventChurn:
    if kind == "event":
        return EventChurn(seed, p_leave, p_return)
    if kind == "dense":
        return DenseChurn(seed, p_leave, p_return)
    raise ValueError(f"unknown churn {kind!r}; pick one of {CHURNS}")
