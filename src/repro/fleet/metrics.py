"""Fleet-scale metrics: what the simulator measures per round and how it
is summarized.

The ROADMAP's "millions of users" claim needs a load-bearing signal:
per-round participation, cancellations, broker traffic (published /
delivered / dropped deltas from the `Broker` counters), simulation ticks
to quorum, and wall time — aggregated into clients/sec and participation
percentiles. `benchmarks/fleet_scale.py` prints these as CSV rows and
`repro.launch.fleet` as a table.

Wall-clock fields are measurement-only: they never feed back into the
simulation, so determinism (same seed -> same aggregate) is unaffected.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.columns import FleetColumns


@dataclass(frozen=True, slots=True)
class RoundMetrics:
    round: int
    online_at_start: int
    participants: int
    canceled: int
    ticks: int  # simulation ticks the round consumed
    published: int  # broker messages published during the round
    delivered: int
    dropped: int
    wall_s: float
    mean_client_loss: float | None = None
    dist_to_optimum: float | None = None

    @property
    def participation(self) -> float:
        return self.participants / max(1, self.online_at_start)


@dataclass(frozen=True, slots=True)
class RoundProgress:
    """Live gauge of the round currently in flight, fed from the same
    O(1) `AssignmentDoc.counts()` status-event counters the deadline
    check reads — progress costs zero extra store scans."""

    round: int
    total: int          # tasks committed this round/window
    finished: int = 0
    error: int = 0
    canceled: int = 0

    @property
    def terminal(self) -> int:
        return self.finished + self.error + self.canceled

    @property
    def active(self) -> int:
        return max(0, self.total - self.terminal)

    @property
    def completion(self) -> float:
        return self.finished / max(1, self.total)

    def to_dict(self) -> dict[str, int | float]:
        """JSON-shaped snapshot — the serve gateway's ``progress`` reads
        return exactly this, so dashboards and tests share one schema."""
        return {
            "round": self.round,
            "total": self.total,
            "finished": self.finished,
            "error": self.error,
            "canceled": self.canceled,
            "active": self.active,
            "completion": self.completion,
        }


@dataclass
class FleetMetrics:
    """Accumulates per-round records and derives fleet-level aggregates."""

    rounds: list[RoundMetrics] = field(default_factory=list)
    #: gauge of the in-flight round (None between rounds' commit/close);
    #: drivers call `begin_round` at commit and `update_progress` on
    #: every counts snapshot, so dashboards can poll completed / failed /
    #: canceled live instead of waiting for the round record
    progress: RoundProgress | None = None
    #: shared per-client arena (repro.core.columns): when attached,
    #: `fleet_gauges` reads fleet-wide state as vectorized reductions
    #: over the same columns the store and services write — a view, not
    #: a copy
    columns: "FleetColumns | None" = None

    def record(self, rec: RoundMetrics) -> None:
        self.rounds.append(rec)

    # -- columnar fleet gauges ------------------------------------------ #
    def fleet_gauges(self) -> dict[str, float]:
        """Instantaneous fleet-wide gauges, each ONE numpy reduction over
        the shared columns: no per-client Python loop, no copies. Empty
        dict when no arena is attached."""
        cols = self.columns
        if cols is None or cols.n_rows == 0:
            return {}
        n = cols.n_rows
        return {
            "clients": n,
            "online": int(np.count_nonzero(cols.online[:n])),
            "registered": int(np.count_nonzero(cols.registered[:n])),
            "runnable": int(np.count_nonzero(cols.runnable[:n])),
            "stragglers": int(np.count_nonzero(cols.straggler[:n])),
            "unacked_results": int(cols.unacked[:n].sum()),
            "mean_clock": float(cols.clock[:n].mean()),
            "max_clock": int(cols.clock[:n].max()),
        }

    # -- live per-round progress (PR 6 follow-up (c)) ------------------- #
    def begin_round(self, round_id: int, total: int) -> None:
        self.progress = RoundProgress(round=round_id, total=total)

    def update_progress(self, counts) -> None:
        """Fold one `TaskCounts` snapshot into the gauge (no-op until
        `begin_round` opens one)."""
        if self.progress is None:
            return
        self.progress = RoundProgress(
            round=self.progress.round,
            total=self.progress.total,
            finished=counts.finished,
            error=counts.error,
            canceled=counts.canceled,
        )

    # ------------------------------------------------------------------ #
    def summary(self) -> dict:
        if not self.rounds:
            return {"rounds": 0}
        parts = np.array([r.participants for r in self.rounds], np.float64)
        ratio = np.array([r.participation for r in self.rounds])
        wall = float(sum(r.wall_s for r in self.rounds))
        total_participants = int(parts.sum())
        return {
            "rounds": len(self.rounds),
            "total_participants": total_participants,
            "clients_per_sec": total_participants / max(wall, 1e-9),
            "wall_s": wall,
            "ticks": int(sum(r.ticks for r in self.rounds)),
            "participation_p50": float(np.percentile(ratio, 50)),
            "participation_p10": float(np.percentile(ratio, 10)),
            "canceled_total": int(sum(r.canceled for r in self.rounds)),
            "published": int(sum(r.published for r in self.rounds)),
            "delivered": int(sum(r.delivered for r in self.rounds)),
            "dropped": int(sum(r.dropped for r in self.rounds)),
            "final_dist_to_optimum": self.rounds[-1].dist_to_optimum,
        }

    def format_table(self) -> str:
        head = (
            f"{'round':>5} {'online':>7} {'clients':>8} {'canceled':>9} "
            f"{'ticks':>6} {'dropped':>8} {'loss':>10} {'dist':>8}"
        )
        lines = [head]
        for r in self.rounds:
            loss = f"{r.mean_client_loss:.4f}" if r.mean_client_loss is not None else "-"
            dist = f"{r.dist_to_optimum:.4f}" if r.dist_to_optimum is not None else "-"
            lines.append(
                f"{r.round:>5} {r.online_at_start:>7} {r.participants:>8} "
                f"{r.canceled:>9} {r.ticks:>6} {r.dropped:>8} {loss:>10} {dist:>8}"
            )
        s = self.summary()
        if s["rounds"]:
            lines.append(
                f"-- {s['rounds']} rounds, {s['total_participants']} client-rounds, "
                f"{s['clients_per_sec']:.0f} clients/s, "
                f"{s['dropped']} notifications dropped"
            )
        return "\n".join(lines)
