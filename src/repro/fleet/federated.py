"""Federated learning over the AutoSPADA platform.

The paper's §8 active-learning use case generalized: a *round* is an
assignment whose tasks carry the current global model as Parameters
(exactly the paper's "distribute a model to many clients" example);
each vehicle client trains locally in its task container and publishes a
(compressed) model delta as a result; the server aggregates whatever
arrived by the deadline (stragglers simply miss the round — state-based
sync means their results surface later and are ignored by round id).

This file holds the pure-JAX math (local SGD, FedAvg aggregation); the
orchestration lives in repro.fleet.rounds (platform-driven) and the
runnable demo in examples/federated_fleet.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.fleet.compression import (
    ErrorFeedback,
    flatten_pytree,
    make_codec,
    unflatten_pytree,
)

Params = Any


@dataclasses.dataclass(frozen=True)
class FedConfig:
    rounds: int = 5
    local_steps: int = 4
    local_lr: float = 0.1
    server_lr: float = 1.0
    codec: str = "int8"  # none | int8 | topk
    codec_kwargs: tuple = ()
    deadline_fraction: float = 1.0  # fraction of clients awaited per round
    #: hard per-round pump budget (simulation ticks); None = wait for the
    #: quorum forever. With a budget the round closes on time with whatever
    #: deltas arrived — the paper's wall-clock deadline semantics.
    deadline_pumps: int | None = None


def local_sgd(
    loss_fn: Callable[[Params, Any], jax.Array],
    params: Params,
    batch: Any,
    *,
    steps: int,
    lr: float,
) -> Params:
    """Plain local SGD (FedAvg's client optimizer)."""

    grad = jax.grad(loss_fn)

    def one(p, _):
        g = grad(p, batch)
        return jax.tree.map(lambda w, gw: w - lr * gw, p, g), None

    out, _ = jax.lax.scan(one, params, None, length=steps)
    return out


def client_delta(
    loss_fn, params: Params, batch: Any, cfg: FedConfig, ef: ErrorFeedback | None
) -> dict[str, Any]:
    """Run local training, return the (optionally compressed) delta msg."""
    new_params = local_sgd(
        loss_fn, params, batch, steps=cfg.local_steps, lr=cfg.local_lr
    )
    delta = jax.tree.map(lambda a, b: a - b, new_params, params)
    flat, treedef, shapes = flatten_pytree(delta)
    if ef is None:
        codec = make_codec(cfg.codec, **dict(cfg.codec_kwargs))
        msg = codec.encode(flat)
    else:
        msg = ef.compress(flat)
    return {"msg": msg, "treedef": treedef, "shapes": shapes}


def aggregate_deltas(
    params: Params,
    deltas: list[dict[str, Any]],
    cfg: FedConfig,
    weights: list[float] | None = None,
) -> Params:
    """FedAvg: weighted mean of decoded deltas applied at server_lr."""
    if not deltas:
        return params
    codec = make_codec(cfg.codec, **dict(cfg.codec_kwargs))
    weights = weights or [1.0] * len(deltas)
    total = sum(weights)
    flat_sum = None
    td, shp = deltas[0]["treedef"], deltas[0]["shapes"]
    for d, w in zip(deltas, weights):
        flat = codec.decode(d["msg"]) * (w / total)
        flat_sum = flat if flat_sum is None else flat_sum + flat
    mean_delta = unflatten_pytree(flat_sum, td, shp)
    return jax.tree.map(
        lambda p, g: (p + cfg.server_lr * g).astype(p.dtype), params, mean_delta
    )


# --------------------------------------------------------------------- #
# secure-aggregation-style pairwise masking (paper §3.5 privacy)         #
# --------------------------------------------------------------------- #
def pairwise_masks(
    n_clients: int, dim: int, seed: int
) -> list[jax.Array]:
    """Zero-sum masks: client i adds sum_j!=i s_ij where s_ij = -s_ji.
    The server learns only the sum of deltas, not any individual one.
    (Single-round, no-dropout variant — dropout recovery would need key
    shares, out of scope; documented in DESIGN.md.)"""
    masks = [jnp.zeros((dim,), jnp.float32) for _ in range(n_clients)]
    for i in range(n_clients):
        for j in range(i + 1, n_clients):
            key = jax.random.PRNGKey(seed * 1_000_003 + i * 1_009 + j)
            s = jax.random.normal(key, (dim,), jnp.float32) * 0.01
            masks[i] = masks[i] + s
            masks[j] = masks[j] - s
    return masks
