"""Deterministic discrete-event fleet simulator.

Drives hundreds-to-thousands of `EdgeClient` instances against one
`StateStore`/`Broker` pair under a *seeded* schedule of

* broker faults — drop / duplicate / delay via `seeded_fault_plan`
  (paper §2.3 intermittent connectivity, §3.3.1 resiliency);
* client churn — vehicles power off and return mid-round through
  `FleetPool.power_off/power_on`, and brand-new vehicles can join
  (`FleetPool.add_vehicle`);
* stragglers — a seeded subset of clients only gets sync-loop budget
  every `straggler_period`-th tick, so they miss round deadlines and the
  driver's cancel path is exercised at scale.

Time is an integer tick. One `tick()`:

1. drains the unified event engine (`repro.fleet.engine.EventEngine`,
   the default): churn toggles, token-bucket service refills, straggler
   releases, and round-deadline timers all pop off ONE time-ordered
   heap in O(events due) — phase ordering (churn < service < timer)
   reproduces the legacy subsystem order exactly;
2. advances the broker clock, releasing delayed messages (`Broker.advance`);
3. advances the fleet's signals — ONE columnar `FleetSignalPlane` step
   (a jit'd drive-cycle scenario from `repro.fleet.scenarios`) instead of
   the old O(n_clients × n_signals) per-vehicle iterator loop;
4. services the fleet's sync loops: `EngineService` under the engine
   (heap-fed refills + wakes, only due/woken clients touched), the
   numpy-masked `FleetServiceScheduler`, or the original
   `DensePollService` O(N) loop. With `Backends(engine="dense")` the
   legacy per-subsystem tick (churn scan, then service sweep) runs
   instead — kept as the bit-for-bit parity oracle. Stragglers get a
   sync-loop budget only every `straggler_period`-th tick; idle clients
   periodically dial in (`resync`) — the paper's recovery story for
   dropped QoS-0 notifications.

Backend selection is typed: `SimConfig.backends` is a `Backends`
sub-config of enum members (`PlaneBackend`, `ServiceBackend`,
`ChurnBackend`, `EngineBackend`); strings coerce, typos raise
`ValueError`, and the legacy `SimConfig(plane=/service=/churn=)` kwargs
still work as overrides.

Everything observable is a deterministic function of `SimConfig`
(including the seed): same config => same event interleaving => same
aggregated model, bit-for-bit. tests/test_simulator.py asserts this and
the stronger fleet-scale idempotent-ingestion property (a lossy schedule
converges to the *exact* fault-free aggregate).
"""
from __future__ import annotations

import dataclasses
import enum
import time
from dataclasses import dataclass
from typing import Callable

import numpy as np

from repro.core.broker import Broker, seeded_fault_plan
from repro.core.columns import FleetColumns, deep_sizeof
from repro.core.server import make_platform
from repro.core.user import User
from repro.fleet.analytics import AnalyticsConfig, AnalyticsDriver
from repro.fleet.churn import make_churn
from repro.fleet.elastic import FleetPool
from repro.fleet.engine import CalendarService, EngineService, EventEngine
from repro.fleet.federated import FedConfig
from repro.fleet.metrics import FleetMetrics, RoundMetrics
from repro.fleet.rounds import FederatedDriver
from repro.fleet.scenarios import build_plane
from repro.fleet.service import make_service


# --------------------------------------------------------------------- #
# typed backend selection (the Backends sub-config)                      #
# --------------------------------------------------------------------- #
class PlaneBackend(str, enum.Enum):
    """Signal-plane implementation: one columnar host array, or rows
    sharded across devices on a `clients` mesh — bit-for-bit identical."""

    HOST = "host"
    SHARDED = "sharded"


class ServiceBackend(str, enum.Enum):
    """Fleet sync-loop service: the event-driven scheduler (O(runnable)
    per tick; engine-native when the engine backend is "event"), the
    calendar-queue service (periodic refills in numpy lanes — the 100k+
    fast path; requires the event engine), or the original dense O(N)
    poll loop, kept as the parity oracle."""

    SCHEDULER = "scheduler"
    CALENDAR = "calendar"
    DENSE = "dense"


class ChurnBackend(str, enum.Enum):
    """Ignition churn: seeded geometric inter-arrival *events* (O(events)
    per tick) or the O(N)-scan oracle over the same per-vehicle streams."""

    EVENT = "event"
    DENSE = "dense"


class EngineBackend(str, enum.Enum):
    """Tick orchestration: "event" drains one unified time-ordered heap
    (churn toggles, service refills, round deadlines — O(events) per
    tick); "dense" is the legacy per-subsystem tick, the parity oracle."""

    EVENT = "event"
    DENSE = "dense"


def _coerce_backend(enum_cls: type, value, knob: str):
    if isinstance(value, enum_cls):
        return value
    try:
        return enum_cls(value)
    except ValueError:
        valid = ", ".join(repr(e.value) for e in enum_cls)
        raise ValueError(
            f"unknown {knob} backend {value!r}; valid choices: {valid}"
        ) from None


@dataclass(frozen=True)
class Backends:
    """Which implementation runs each simulator subsystem.

    Every knob is a typed enum; plain strings are accepted and coerced
    (``Backends(plane="sharded")``), and a typo raises a ValueError
    naming the valid choices. Each "dense" choice is the corresponding
    O(N) parity oracle — any mix must yield bit-for-bit identical runs.
    """

    plane: PlaneBackend = PlaneBackend.HOST
    service: ServiceBackend = ServiceBackend.SCHEDULER
    churn: ChurnBackend = ChurnBackend.EVENT
    engine: EngineBackend = EngineBackend.EVENT

    def __post_init__(self):
        object.__setattr__(
            self, "plane", _coerce_backend(PlaneBackend, self.plane, "plane")
        )
        object.__setattr__(
            self, "service",
            _coerce_backend(ServiceBackend, self.service, "service"),
        )
        object.__setattr__(
            self, "churn", _coerce_backend(ChurnBackend, self.churn, "churn")
        )
        object.__setattr__(
            self, "engine",
            _coerce_backend(EngineBackend, self.engine, "engine"),
        )


@dataclass(frozen=True)
class SimConfig:
    """Everything that determines a simulation, seed included."""

    n_clients: int = 32
    seed: int = 0
    # -- signals --------------------------------------------------------- #
    #: drive-cycle scenario feeding the columnar signal plane
    #: (repro.fleet.scenarios.SCENARIOS). The default is the legacy
    #: time-invariant per-vehicle road grade, so rounds that consume
    #: different tick counts (lossy vs fault-free) see identical signals.
    scenario: str = "road-grade"
    #: plane history ring depth (backs `autospada.get_signal_window`)
    signal_history: int = 256
    # -- broker faults -------------------------------------------------- #
    p_drop: float = 0.0        # QoS-0 notification drop probability
    p_duplicate: float = 0.0   # QoS-1 redelivery probability
    max_delay: int = 0         # uniform message delay in ticks
    # -- churn ---------------------------------------------------------- #
    p_leave: float = 0.0       # per-online-client per-tick ignition-off
    p_return: float = 0.0      # per-offline-client per-tick ignition-on
    # -- stragglers ----------------------------------------------------- #
    straggler_fraction: float = 0.0
    straggler_period: int = 4  # stragglers act once every `period` ticks
    # -- service rates -------------------------------------------------- #
    steps_per_tick: int = 8    # sync-loop op budget per client per tick
    resync_period: int = 4     # idle clients dial in every k ticks
    # -- backend selection ---------------------------------------------- #
    #: typed per-subsystem implementation choices. The four legacy
    #: top-level knobs below stay accepted (strings or enums) and
    #: override the corresponding `backends` field; after construction
    #: they mirror the resolved enum values, so `cfg.plane == "host"`
    #: style comparisons keep working.
    backends: Backends | None = None
    plane: PlaneBackend | str | None = None
    service: ServiceBackend | str | None = None
    churn: ChurnBackend | str | None = None
    engine: EngineBackend | str | None = None

    def __post_init__(self):
        b = self.backends if self.backends is not None else Backends()
        if not isinstance(b, Backends):
            raise TypeError(
                f"backends must be a Backends, got {type(b).__name__}"
            )
        overrides = {
            knob: v
            for knob in ("plane", "service", "churn", "engine")
            if (v := getattr(self, knob)) is not None
        }
        if overrides:
            # replace() re-runs Backends.__post_init__, coercing strings
            # and raising the naming ValueError on typos
            b = dataclasses.replace(b, **overrides)
        object.__setattr__(self, "backends", b)
        for knob in ("plane", "service", "churn", "engine"):
            object.__setattr__(self, knob, getattr(b, knob))


class FleetSimulator:
    """Owns the platform (store + broker + server), the vehicle pool, and
    logical time. `tick` doubles as the `pump` callable every platform
    driver in this repo expects, so the simulator slots in wherever the
    old hand-written pump loops did."""

    def __init__(
        self,
        cfg: SimConfig,
        *,
        signal_fn: Callable[[int], dict] | None = None,
    ):
        self.cfg = cfg
        b = cfg.backends
        faults = seeded_fault_plan(
            cfg.seed,
            p_drop=cfg.p_drop,
            p_duplicate=cfg.p_duplicate,
            max_delay=cfg.max_delay,
        )
        self.broker = Broker(faults)
        self.store, _, (self.server,) = make_platform(broker=self.broker)
        # the columnar control plane: ONE structure-of-arrays arena holds
        # every per-client scalar (logical clocks, power/registered flags,
        # sync timestamps, unacked counts, service gating). Attached to
        # the store BEFORE the pool registers vehicles, so arena rows are
        # allocated in vehicle-index order.
        self.columns = FleetColumns(cfg.n_clients)
        self.store.attach_columns(self.columns)
        #: the unified event heap (None under the legacy dense tick path)
        self.engine = (
            EventEngine(self.broker)
            if b.engine is EngineBackend.EVENT
            else None
        )
        # Signals: an explicit signal_fn keeps the legacy per-vehicle
        # scripted path; otherwise the whole fleet shares one columnar
        # signal plane seeded from the configured drive-cycle scenario.
        self.plane = (
            None
            if signal_fn is not None
            else build_plane(
                cfg.scenario,
                cfg.n_clients,
                cfg.seed,
                history=cfg.signal_history,
                plane=b.plane.value,
            )
        )
        self.pool = FleetPool(
            self.store,
            self.broker,
            self.server,
            n_vehicles=cfg.n_clients,
            signal_fn=signal_fn,
            plane=self.plane,
            columns=self.columns,
            seed=cfg.seed,
        )
        self.user = User(self.server, self.broker)
        self.metrics = FleetMetrics(columns=self.columns)
        self.t = 0
        # churn: seeded geometric *event times* per vehicle (O(events) per
        # tick) instead of a per-vehicle per-tick coin; each vehicle draws
        # from its own stream so adding a fault knob — or another vehicle —
        # never perturbs who leaves when
        self.churn = make_churn(
            b.churn.value, cfg.seed, cfg.p_leave, cfg.p_return
        )
        if self.engine is not None and b.churn is ChurnBackend.EVENT:
            # toggle events live in the unified heap; the dense-churn
            # oracle keeps its scan and is applied before the drain
            self.churn.attach_engine(self.engine, self._toggle_ignition)
        self.pool.attach_churn(self.churn)
        for cid, v in self.pool.vehicles.items():
            self.churn.watch(
                cid, v.metadata["index"], v.client is not None, now=0
            )
        # seeded straggler subset: a fixed permutation prefix
        order = np.random.default_rng((cfg.seed, 0x57A6)).permutation(
            cfg.n_clients
        )
        k = int(round(cfg.n_clients * cfg.straggler_fraction))
        slow = set(int(i) for i in order[:k])
        # let the initial bootstrap traffic settle so round 0 starts from
        # a quiesced fleet regardless of fleet size
        for v in self.pool.vehicles.values():
            if v.client is not None:
                v.client.run_until_idle()
        # fleet service: event-driven scheduler (default; engine-native
        # when the engine backend is "event") or the dense poll-loop
        # oracle — attached after the quiesce so the scheduler's runnable
        # set starts from the fleet's true (idle) state
        if b.service is ServiceBackend.CALENDAR and self.engine is None:
            raise ValueError(
                "service backend 'calendar' needs the event engine "
                "(Backends(engine='event')) — its lanes fire from the drain"
            )
        if self.engine is not None and b.service in (
            ServiceBackend.SCHEDULER, ServiceBackend.CALENDAR
        ):
            service_cls = (
                CalendarService
                if b.service is ServiceBackend.CALENDAR
                else EngineService
            )
            self.service = service_cls(
                self.engine,
                self.pool,
                steps_per_tick=cfg.steps_per_tick,
                resync_period=cfg.resync_period,
                straggler_period=cfg.straggler_period,
                straggler_indices=slow,
            )
        else:
            self.service = make_service(
                b.service.value,
                self.pool,
                steps_per_tick=cfg.steps_per_tick,
                resync_period=cfg.resync_period,
                straggler_period=cfg.straggler_period,
                straggler_indices=slow,
            )
        self.pool.attach_service(self.service)

    # ------------------------------------------------------------------ #
    # the discrete-event loop                                            #
    # ------------------------------------------------------------------ #
    def _toggle_ignition(self, cid: str) -> None:
        """One churn-driven power transition; `notify` re-enters the
        schedule via `FleetPool.attach_churn` to draw the next gap."""
        if self.pool.vehicles[cid].client is not None:
            self.pool.power_off(cid)
        else:
            self.pool.power_on(cid)

    def tick(self) -> None:
        """One world step. Deterministic given the config."""
        self.t += 1
        cfg = self.cfg
        # 1. due events: one drain of the unified heap fires this tick's
        #    ignition toggles, service refills, and deadline timers in
        #    (tick, phase, index) order — O(events), never O(N). The
        #    legacy path (engine="dense") pops each subsystem separately;
        #    the dense-churn oracle keeps its scan in either mode.
        if self.engine is not None:
            if self.churn._engine is None and (cfg.p_leave or cfg.p_return):
                for cid in self.churn.pop_due(self.t):
                    self._toggle_ignition(cid)
            self.engine.drain(self.t)
        elif cfg.p_leave or cfg.p_return:
            for cid in self.churn.pop_due(self.t):
                self._toggle_ignition(cid)
        # 2. release delayed broker deliveries due at this tick
        self.broker.advance(1)
        # 3. advance the whole fleet's signals: ONE columnar plane step
        #    (the old path ticked n_clients iterator brokers in Python).
        #    Scripted signals keep the historical behaviour: a powered-off
        #    vehicle's iterators pause until the ignition returns.
        self.pool.tick_signals(online_only=True)
        # 4. bounded sync-loop service: O(runnable) via the event-driven
        #    scheduler (or the dense O(N) oracle — identical interleaving)
        self.service.tick(self.t)

    # `pump` alias: FederatedDriver and AssignmentDoc.await_results take a
    # zero-arg world-advancer
    def pump(self) -> None:
        self.tick()

    # ------------------------------------------------------------------ #
    # memory accounting                                                  #
    # ------------------------------------------------------------------ #
    def memory_report(self) -> dict[str, int | float]:
        """Bytes per subsystem (recursive `deep_sizeof` walk) plus the
        headline bytes/client figure. One shared identity memo across
        categories, walked in order, so shared structures (the arena, the
        store the clients reference) are billed to the first category
        that reaches them and never double-counted."""
        seen: set[int] = set()
        plane_b = deep_sizeof(self.plane, seen) if self.plane is not None else 0
        cols_b = deep_sizeof(self.columns, seen)
        docs_b = deep_sizeof(self.store, seen)
        queues_b = deep_sizeof(self.broker, seen)
        clients_b = deep_sizeof(self.pool, seen)
        other_b = deep_sizeof(self.service, seen) + deep_sizeof(
            self.churn, seen
        )
        if self.engine is not None:
            other_b += deep_sizeof(self.engine, seen)
        total = plane_b + cols_b + docs_b + queues_b + clients_b + other_b
        n = len(self.pool.vehicles)
        return {
            "n_clients": n,
            "plane": plane_b,
            "columns": cols_b,
            "docs": docs_b,
            "queues": queues_b,
            "clients": clients_b,
            "other": other_b,
            "total": total,
            "bytes_per_client": total / max(1, n),
        }

    @staticmethod
    def format_memory_report(report: dict[str, int | float]) -> str:
        """The `launch.fleet --memory-report` table."""
        lines = [
            f"memory report ({report['n_clients']} clients)",
            "  section      bytes        bytes/client",
        ]
        n = max(1, int(report["n_clients"]))
        for key in ("plane", "columns", "docs", "queues", "clients", "other",
                    "total"):
            b = int(report[key])
            lines.append(f"  {key:<11}{b:>12,}{b / n:>15,.1f}")
        return "\n".join(lines)

    # ------------------------------------------------------------------ #
    # federated-learning campaign                                        #
    # ------------------------------------------------------------------ #
    def run_federated(
        self,
        fed: FedConfig,
        *,
        dim: int = 32,
        w_true: np.ndarray | None = None,
        rounds: int = 5,
        n_samples: int = 32,
        driver: FederatedDriver | None = None,
        on_round: Callable[[int, FederatedDriver], None] | None = None,
    ) -> FederatedDriver:
        """Run `rounds` FedAvg rounds over the simulated fleet, recording
        per-round `RoundMetrics`. Returns the driver (final model in
        `driver.w`, per-round records in `driver.history`).

        Pass a `driver` (e.g. one restored by `FleetCheckpoint.restore`)
        to run `rounds` MORE rounds, continuing the numbering where its
        history left off. `on_round(rnd, driver)` fires after each round
        is recorded — the hook `launch.fleet --checkpoint-every` uses to
        save durable checkpoints mid-campaign."""
        start = 0
        if driver is None:
            if w_true is None:
                w_true = np.sin(np.linspace(0.0, 3.0, dim)).astype(np.float32)
            driver = FederatedDriver(
                self.user,
                fed,
                dim=dim,
                w_true=w_true,
                n_samples=n_samples,
                engine=self.engine,
                status_oracle=self.engine is None,
                metrics=self.metrics,
            )
        else:
            start = len(driver.history)
        for rnd in range(start, start + rounds):
            online = len(self.pool.online())
            t0, tick0 = time.perf_counter(), self.t
            pub0, del0, drop0 = (
                self.broker.published,
                self.broker.delivered,
                self.broker.dropped,
            )
            rec = driver.run_round(rnd, pump=self.tick)
            self.metrics.record(
                RoundMetrics(
                    round=rnd,
                    online_at_start=online,
                    participants=rec["participants"],
                    canceled=rec["canceled"],
                    ticks=self.t - tick0,
                    published=self.broker.published - pub0,
                    delivered=self.broker.delivered - del0,
                    dropped=self.broker.dropped - drop0,
                    wall_s=time.perf_counter() - t0,
                    mean_client_loss=rec["mean_client_loss"],
                    dist_to_optimum=rec["dist_to_optimum"],
                )
            )
            if on_round is not None:
                on_round(rnd, driver)
        return driver

    # ------------------------------------------------------------------ #
    # streaming-analytics campaign (the paper's data-analytics use case)  #
    # ------------------------------------------------------------------ #
    def run_analytics(
        self,
        cfg: AnalyticsConfig,
        *,
        windows: int = 5,
        warmup_ticks: int = 0,
        driver: AnalyticsDriver | None = None,
        on_window: Callable[[int, AnalyticsDriver], None] | None = None,
    ) -> AnalyticsDriver:
        """Run `windows` streaming-statistics assignments over the fleet:
        vehicles fold their signal windows into Welford/histogram sketches
        on-board; the server merges all sketches in one batched jit
        reduction per window. `warmup_ticks` advances the world first so
        the signal plane's history ring has data to window over.

        Pass a `driver` (e.g. one restored by `FleetCheckpoint.restore`)
        to run `windows` MORE windows continuing its history (warmup is
        skipped — the restored world already carries the ring).
        `on_window(w, driver)` fires after each window is recorded."""
        start = 0
        if driver is None:
            for _ in range(warmup_ticks):
                self.tick()
            driver = AnalyticsDriver(
                self.user,
                cfg,
                engine=self.engine,
                status_oracle=self.engine is None,
                metrics=self.metrics,
            )
        else:
            start = len(driver.history)
        for w in range(start, start + windows):
            online = len(self.pool.online())
            t0, tick0 = time.perf_counter(), self.t
            pub0, del0, drop0 = (
                self.broker.published,
                self.broker.delivered,
                self.broker.dropped,
            )
            rec = driver.run_window(w, pump=self.tick)
            self.metrics.record(
                RoundMetrics(
                    round=w,
                    online_at_start=online,
                    participants=rec.participants,
                    canceled=rec.canceled,
                    ticks=self.t - tick0,
                    published=self.broker.published - pub0,
                    delivered=self.broker.delivered - del0,
                    dropped=self.broker.dropped - drop0,
                    wall_s=time.perf_counter() - t0,
                )
            )
            if on_window is not None:
                on_window(w, driver)
        return driver
