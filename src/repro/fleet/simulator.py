"""Deterministic discrete-event fleet simulator.

Drives hundreds-to-thousands of `EdgeClient` instances against one
`StateStore`/`Broker` pair under a *seeded* schedule of

* broker faults — drop / duplicate / delay via `seeded_fault_plan`
  (paper §2.3 intermittent connectivity, §3.3.1 resiliency);
* client churn — vehicles power off and return mid-round through
  `FleetPool.power_off/power_on`, and brand-new vehicles can join
  (`FleetPool.add_vehicle`);
* stragglers — a seeded subset of clients only gets sync-loop budget
  every `straggler_period`-th tick, so they miss round deadlines and the
  driver's cancel path is exercised at scale.

Time is an integer tick. One `tick()`:

1. applies the churn toggles *due* this tick — seeded geometric
   inter-arrival event times per vehicle (`repro.fleet.churn`), popped
   from a heap in O(events), not one RNG draw per vehicle per tick;
2. advances the broker clock, releasing delayed messages (`Broker.advance`);
3. advances the fleet's signals — ONE columnar `FleetSignalPlane` step
   (a jit'd drive-cycle scenario from `repro.fleet.scenarios`) instead of
   the old O(n_clients × n_signals) per-vehicle iterator loop;
4. services the fleet's sync loops through the configured fleet service
   (`repro.fleet.service`): the event-driven `FleetServiceScheduler` by
   default — wake hooks make clients runnable, vectorized phase masks
   gate stragglers/resyncs, and only runnable clients are touched — or
   the original `DensePollService` O(N) loop (`SimConfig.service =
   "dense"`), kept as the bit-for-bit parity oracle. Stragglers get a
   sync-loop budget only every `straggler_period`-th tick; idle clients
   periodically dial in (`resync`) — the paper's recovery story for
   dropped QoS-0 notifications.

Everything observable is a deterministic function of `SimConfig`
(including the seed): same config => same event interleaving => same
aggregated model, bit-for-bit. tests/test_simulator.py asserts this and
the stronger fleet-scale idempotent-ingestion property (a lossy schedule
converges to the *exact* fault-free aggregate).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.broker import Broker, seeded_fault_plan
from repro.core.server import make_platform
from repro.core.user import User
from repro.fleet.analytics import AnalyticsConfig, AnalyticsDriver
from repro.fleet.churn import make_churn
from repro.fleet.elastic import FleetPool
from repro.fleet.federated import FedConfig
from repro.fleet.metrics import FleetMetrics, RoundMetrics
from repro.fleet.rounds import FederatedDriver
from repro.fleet.scenarios import build_plane
from repro.fleet.service import make_service


@dataclass(frozen=True)
class SimConfig:
    """Everything that determines a simulation, seed included."""

    n_clients: int = 32
    seed: int = 0
    # -- signals --------------------------------------------------------- #
    #: drive-cycle scenario feeding the columnar signal plane
    #: (repro.fleet.scenarios.SCENARIOS). The default is the legacy
    #: time-invariant per-vehicle road grade, so rounds that consume
    #: different tick counts (lossy vs fault-free) see identical signals.
    scenario: str = "road-grade"
    #: plane history ring depth (backs `autospada.get_signal_window`)
    signal_history: int = 256
    #: signal-plane implementation: "host" (one columnar host array) or
    #: "sharded" (rows sharded across devices on a `clients` mesh — the
    #: million-vehicle layout; bit-for-bit identical to "host")
    plane: str = "host"
    # -- broker faults -------------------------------------------------- #
    p_drop: float = 0.0        # QoS-0 notification drop probability
    p_duplicate: float = 0.0   # QoS-1 redelivery probability
    max_delay: int = 0         # uniform message delay in ticks
    # -- churn ---------------------------------------------------------- #
    p_leave: float = 0.0       # per-online-client per-tick ignition-off
    p_return: float = 0.0      # per-offline-client per-tick ignition-on
    # -- stragglers ----------------------------------------------------- #
    straggler_fraction: float = 0.0
    straggler_period: int = 4  # stragglers act once every `period` ticks
    # -- service rates -------------------------------------------------- #
    steps_per_tick: int = 8    # sync-loop op budget per client per tick
    resync_period: int = 4     # idle clients dial in every k ticks
    #: fleet service implementation: "scheduler" (event-driven runnable
    #: set, O(runnable) per tick) or "dense" (the original O(N) poll loop,
    #: kept as the parity oracle — both yield identical interleavings)
    service: str = "scheduler"
    #: churn implementation: "event" (seeded geometric inter-arrival
    #: times per vehicle, O(events)/tick via a heap) or "dense" (the
    #: O(N)-scan oracle over the same per-vehicle event streams — the
    #: parity witness, identical toggle sequences)
    churn: str = "event"


class FleetSimulator:
    """Owns the platform (store + broker + server), the vehicle pool, and
    logical time. `tick` doubles as the `pump` callable every platform
    driver in this repo expects, so the simulator slots in wherever the
    old hand-written pump loops did."""

    def __init__(
        self,
        cfg: SimConfig,
        *,
        signal_fn: Callable[[int], dict] | None = None,
    ):
        self.cfg = cfg
        faults = seeded_fault_plan(
            cfg.seed,
            p_drop=cfg.p_drop,
            p_duplicate=cfg.p_duplicate,
            max_delay=cfg.max_delay,
        )
        self.broker = Broker(faults)
        self.store, _, (self.server,) = make_platform(broker=self.broker)
        # Signals: an explicit signal_fn keeps the legacy per-vehicle
        # scripted path; otherwise the whole fleet shares one columnar
        # signal plane seeded from the configured drive-cycle scenario.
        self.plane = (
            None
            if signal_fn is not None
            else build_plane(
                cfg.scenario,
                cfg.n_clients,
                cfg.seed,
                history=cfg.signal_history,
                plane=cfg.plane,
            )
        )
        self.pool = FleetPool(
            self.store,
            self.broker,
            self.server,
            n_vehicles=cfg.n_clients,
            signal_fn=signal_fn,
            plane=self.plane,
            seed=cfg.seed,
        )
        self.user = User(self.server, self.broker)
        self.metrics = FleetMetrics()
        self.t = 0
        # churn: seeded geometric *event times* per vehicle (O(events) per
        # tick) instead of a per-vehicle per-tick coin; each vehicle draws
        # from its own stream so adding a fault knob — or another vehicle —
        # never perturbs who leaves when
        self.churn = make_churn(cfg.churn, cfg.seed, cfg.p_leave, cfg.p_return)
        self.pool.attach_churn(self.churn)
        for cid, v in self.pool.vehicles.items():
            self.churn.watch(
                cid, v.metadata["index"], v.client is not None, now=0
            )
        # seeded straggler subset: a fixed permutation prefix
        order = np.random.default_rng((cfg.seed, 0x57A6)).permutation(
            cfg.n_clients
        )
        k = int(round(cfg.n_clients * cfg.straggler_fraction))
        slow = set(int(i) for i in order[:k])
        # let the initial bootstrap traffic settle so round 0 starts from
        # a quiesced fleet regardless of fleet size
        for v in self.pool.vehicles.values():
            if v.client is not None:
                v.client.run_until_idle()
        # fleet service: event-driven scheduler (default) or the dense
        # poll-loop oracle — attached after the quiesce so the scheduler's
        # runnable set starts from the fleet's true (idle) state
        self.service = make_service(
            cfg.service,
            self.pool,
            steps_per_tick=cfg.steps_per_tick,
            resync_period=cfg.resync_period,
            straggler_period=cfg.straggler_period,
            straggler_indices=slow,
        )
        self.pool.attach_service(self.service)

    # ------------------------------------------------------------------ #
    # the discrete-event loop                                            #
    # ------------------------------------------------------------------ #
    def tick(self) -> None:
        """One world step. Deterministic given the config."""
        self.t += 1
        cfg = self.cfg
        # 1. churn: pop the ignition toggles due this tick (fleet order) —
        #    O(events), not O(N); the power transition re-enters the
        #    schedule via `FleetPool.attach_churn` to draw the next gap
        if cfg.p_leave or cfg.p_return:
            for cid in self.churn.pop_due(self.t):
                if self.pool.vehicles[cid].client is not None:
                    self.pool.power_off(cid)
                else:
                    self.pool.power_on(cid)
        # 2. release delayed broker deliveries due at this tick
        self.broker.advance(1)
        # 3. advance the whole fleet's signals: ONE columnar plane step
        #    (the old path ticked n_clients iterator brokers in Python).
        #    Scripted signals keep the historical behaviour: a powered-off
        #    vehicle's iterators pause until the ignition returns.
        self.pool.tick_signals(online_only=True)
        # 4. bounded sync-loop service: O(runnable) via the event-driven
        #    scheduler (or the dense O(N) oracle — identical interleaving)
        self.service.tick(self.t)

    # `pump` alias: FederatedDriver and AssignmentDoc.await_results take a
    # zero-arg world-advancer
    def pump(self) -> None:
        self.tick()

    # ------------------------------------------------------------------ #
    # federated-learning campaign                                        #
    # ------------------------------------------------------------------ #
    def run_federated(
        self,
        fed: FedConfig,
        *,
        dim: int = 32,
        w_true: np.ndarray | None = None,
        rounds: int = 5,
        n_samples: int = 32,
    ) -> FederatedDriver:
        """Run `rounds` FedAvg rounds over the simulated fleet, recording
        per-round `RoundMetrics`. Returns the driver (final model in
        `driver.w`, per-round records in `driver.history`)."""
        if w_true is None:
            w_true = np.sin(np.linspace(0.0, 3.0, dim)).astype(np.float32)
        driver = FederatedDriver(
            self.user, fed, dim=dim, w_true=w_true, n_samples=n_samples
        )
        for rnd in range(rounds):
            online = len(self.pool.online())
            t0, tick0 = time.perf_counter(), self.t
            pub0, del0, drop0 = (
                self.broker.published,
                self.broker.delivered,
                self.broker.dropped,
            )
            rec = driver.run_round(rnd, pump=self.tick)
            self.metrics.record(
                RoundMetrics(
                    round=rnd,
                    online_at_start=online,
                    participants=rec["participants"],
                    canceled=rec["canceled"],
                    ticks=self.t - tick0,
                    published=self.broker.published - pub0,
                    delivered=self.broker.delivered - del0,
                    dropped=self.broker.dropped - drop0,
                    wall_s=time.perf_counter() - t0,
                    mean_client_loss=rec["mean_client_loss"],
                    dist_to_optimum=rec["dist_to_optimum"],
                )
            )
        return driver

    # ------------------------------------------------------------------ #
    # streaming-analytics campaign (the paper's data-analytics use case)  #
    # ------------------------------------------------------------------ #
    def run_analytics(
        self,
        cfg: AnalyticsConfig,
        *,
        windows: int = 5,
        warmup_ticks: int = 0,
    ) -> AnalyticsDriver:
        """Run `windows` streaming-statistics assignments over the fleet:
        vehicles fold their signal windows into Welford/histogram sketches
        on-board; the server merges all sketches in one batched jit
        reduction per window. `warmup_ticks` advances the world first so
        the signal plane's history ring has data to window over."""
        for _ in range(warmup_ticks):
            self.tick()
        driver = AnalyticsDriver(self.user, cfg)
        for w in range(windows):
            online = len(self.pool.online())
            t0, tick0 = time.perf_counter(), self.t
            pub0, del0, drop0 = (
                self.broker.published,
                self.broker.delivered,
                self.broker.dropped,
            )
            rec = driver.run_window(w, pump=self.tick)
            self.metrics.record(
                RoundMetrics(
                    round=w,
                    online_at_start=online,
                    participants=rec.participants,
                    canceled=rec.canceled,
                    ticks=self.t - tick0,
                    published=self.broker.published - pub0,
                    delivered=self.broker.delivered - del0,
                    dropped=self.broker.dropped - drop0,
                    wall_s=time.perf_counter() - t0,
                )
            )
        return driver
