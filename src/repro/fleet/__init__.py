from repro.fleet.analytics import (
    AnalyticsConfig,
    AnalyticsDriver,
    WindowStats,
    merge_moments_reference,
)
from repro.fleet.compression import (
    ErrorFeedback,
    batched_dequant_mean,
    make_codec,
)
from repro.fleet.federated import FedConfig, aggregate_deltas, client_delta, local_sgd
from repro.fleet.elastic import FleetPool
from repro.fleet.metrics import FleetMetrics, RoundMetrics
from repro.fleet.rounds import (
    FederatedDriver,
    aggregate_packed,
    aggregate_reference,
    mean_reported_loss,
    pump_until_deadline,
    stack_deltas,
)
from repro.fleet.scenarios import SCENARIOS, SIGNALS, Scenario, build_plane
from repro.fleet.service import (
    DensePollService,
    FleetServiceScheduler,
    make_service,
)
from repro.fleet.simulator import FleetSimulator, SimConfig

__all__ = [
    "AnalyticsConfig", "AnalyticsDriver", "DensePollService",
    "ErrorFeedback", "FedConfig", "FederatedDriver", "FleetMetrics",
    "FleetPool", "FleetServiceScheduler", "FleetSimulator", "RoundMetrics",
    "SCENARIOS", "SIGNALS", "Scenario", "SimConfig", "WindowStats",
    "aggregate_deltas", "aggregate_packed", "aggregate_reference",
    "batched_dequant_mean", "build_plane", "client_delta", "local_sgd",
    "make_codec", "make_service", "mean_reported_loss",
    "merge_moments_reference", "pump_until_deadline", "stack_deltas",
]
