from repro.fleet.compression import (
    ErrorFeedback,
    batched_dequant_mean,
    make_codec,
)
from repro.fleet.federated import FedConfig, aggregate_deltas, client_delta, local_sgd
from repro.fleet.elastic import FleetPool
from repro.fleet.metrics import FleetMetrics, RoundMetrics
from repro.fleet.rounds import (
    FederatedDriver,
    aggregate_packed,
    aggregate_reference,
    stack_deltas,
)
from repro.fleet.simulator import FleetSimulator, SimConfig

__all__ = [
    "ErrorFeedback", "FedConfig", "FederatedDriver", "FleetMetrics",
    "FleetPool", "FleetSimulator", "RoundMetrics", "SimConfig",
    "aggregate_deltas", "aggregate_packed", "aggregate_reference",
    "batched_dequant_mean", "client_delta", "local_sgd", "make_codec",
    "stack_deltas",
]
