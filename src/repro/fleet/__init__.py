from repro.fleet.compression import ErrorFeedback, make_codec
from repro.fleet.federated import FedConfig, aggregate_deltas, client_delta, local_sgd
from repro.fleet.elastic import FleetPool
from repro.fleet.rounds import FederatedDriver

__all__ = [
    "ErrorFeedback", "FedConfig", "FederatedDriver", "FleetPool",
    "aggregate_deltas", "client_delta", "local_sgd", "make_codec",
]
