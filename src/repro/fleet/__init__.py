from repro.core.plane_sharded import ShardedSignalPlane
from repro.fleet.analytics import (
    AnalyticsConfig,
    AnalyticsDriver,
    WindowInFlight,
    WindowStats,
    merge_moments_reference,
)
from repro.fleet.checkpoint import CheckpointError, FleetCheckpoint
from repro.fleet.churn import DenseChurn, EventChurn, geometric_gap, make_churn
from repro.fleet.engine import (
    PHASE_ADMIT,
    PHASE_CHURN,
    PHASE_SERVICE,
    PHASE_TIMER,
    EngineService,
    EventEngine,
)
from repro.fleet.compression import (
    ErrorFeedback,
    batched_dequant_mean,
    make_codec,
)
from repro.fleet.federated import FedConfig, aggregate_deltas, client_delta, local_sgd
from repro.fleet.elastic import FleetPool
from repro.fleet.metrics import FleetMetrics, RoundMetrics
from repro.fleet.rounds import (
    DeadlinePump,
    FederatedDriver,
    RoundInFlight,
    aggregate_packed,
    aggregate_reference,
    mean_reported_loss,
    pump_until_deadline,
    stack_deltas,
)
from repro.fleet.scenarios import PLANES, SCENARIOS, SIGNALS, Scenario, build_plane
from repro.fleet.service import (
    DensePollService,
    FleetServiceScheduler,
    make_service,
)
from repro.fleet.simulator import (
    Backends,
    ChurnBackend,
    EngineBackend,
    FleetSimulator,
    PlaneBackend,
    ServiceBackend,
    SimConfig,
)

__all__ = [
    "AnalyticsConfig", "AnalyticsDriver", "Backends", "CheckpointError",
    "ChurnBackend", "DeadlinePump", "DenseChurn", "DensePollService",
    "EngineBackend", "EngineService", "ErrorFeedback", "EventChurn",
    "EventEngine", "FedConfig", "FederatedDriver", "FleetCheckpoint",
    "FleetMetrics", "FleetPool", "FleetServiceScheduler", "FleetSimulator",
    "PHASE_ADMIT", "PHASE_CHURN", "PHASE_SERVICE", "PHASE_TIMER", "PLANES",
    "PlaneBackend", "RoundInFlight", "RoundMetrics", "SCENARIOS",
    "SIGNALS", "Scenario", "ServiceBackend", "ShardedSignalPlane",
    "SimConfig", "WindowInFlight", "WindowStats", "aggregate_deltas",
    "aggregate_packed", "aggregate_reference", "batched_dequant_mean",
    "build_plane", "client_delta", "geometric_gap", "local_sgd",
    "make_churn", "make_codec", "make_service", "mean_reported_loss",
    "merge_moments_reference", "pump_until_deadline", "stack_deltas",
]
