"""Checkpoint/restart THROUGH the AutoSPADA control plane.

This is the paper's resiliency mechanism applied to training (DESIGN.md
§2): a training job is an *assignment*; each pod-host is a platform
*client*; a checkpoint is an *intermediate result* that is cached locally
until the server acknowledges it as recorded — after which the step is
durable. A restarted (preempted) pod fetches its state snapshot, reads the
latest acknowledged checkpoint id from the task's results, and resumes
from the matching blob.

Tensor payloads live in a blob store (filesystem here; GCS/S3 in a real
deployment) — only metadata + logical clocks flow through the document
store, the same split the paper makes between MongoDB documents and bulk
results.
"""
from __future__ import annotations

import hashlib
import io
import json
import os
from pathlib import Path
from typing import Any

import numpy as np

#: on-disk blob format tag; bump when the layout changes
BLOB_FORMAT = "npy-tree/1"


def _render_npy(arr: np.ndarray) -> bytes:
    """The exact bytes ``np.save`` would write — rendered in memory so
    the content hash is computed from what lands on disk."""
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    return buf.getvalue()


def _flatten(obj: Any, leaves: list[np.ndarray]) -> Any:
    """JSON skeleton of a pytree of dict/list/tuple/None containers;
    leaves are appended to ``leaves`` in skeleton order (dict keys
    sorted, so the order is a pure function of the value)."""
    if obj is None:
        return {"t": "none"}
    if isinstance(obj, dict):
        keys = sorted(obj)
        if not all(isinstance(k, str) for k in keys):
            raise TypeError(f"BlobStore dict keys must be str, got {keys!r}")
        return {"t": "dict", "k": keys,
                "v": [_flatten(obj[k], leaves) for k in keys]}
    if isinstance(obj, (list, tuple)):
        return {"t": "list" if isinstance(obj, list) else "tuple",
                "v": [_flatten(x, leaves) for x in obj]}
    leaves.append(np.asarray(obj))
    return {"t": "leaf", "i": len(leaves) - 1}


def _unflatten(skel: Any, leaves: list[np.ndarray]) -> Any:
    t = skel["t"]
    if t == "none":
        return None
    if t == "dict":
        return {k: _unflatten(v, leaves) for k, v in zip(skel["k"], skel["v"])}
    if t == "list":
        return [_unflatten(v, leaves) for v in skel["v"]]
    if t == "tuple":
        return tuple(_unflatten(v, leaves) for v in skel["v"])
    if t == "leaf":
        return leaves[skel["i"]]
    raise ValueError(f"unknown skeleton node type {t!r}")


class BlobStore:
    """Content-addressed tensor blobs on disk.

    A blob is a JSON manifest (``{name}.json``) holding the container
    skeleton plus one raw ``.npy`` file per leaf, named by the sha256 of
    its bytes. Raw ``np.save`` bytes are a pure function of the array
    (dtype + shape + data), so identical leaves dedup across blobs and
    re-saving identical state writes identical files — pickled treedefs
    (the old format) embedded class identities and made hashes drift
    across runs.
    """

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def put(
        self,
        name: str,
        tree: Any,
        *,
        link_from: "BlobStore | str | Path | None" = None,
    ) -> str:
        """Write a blob. With ``link_from`` (a previous checkpoint's
        store), any leaf whose content-addressed file already exists
        there is hardlinked instead of rewritten — an incremental save
        costs disk and I/O only for the leaves that actually changed.
        Falls back to a plain write where hardlinks are unsupported
        (cross-device stores); `get`'s hash check still guards reads."""
        src_root: Path | None = None
        if link_from is not None:
            src_root = (link_from.root if isinstance(link_from, BlobStore)
                        else Path(link_from))
        leaves: list[np.ndarray] = []
        skeleton = _flatten(tree, leaves)
        entries = []
        for leaf in leaves:
            raw = _render_npy(leaf)
            digest = hashlib.sha256(raw).hexdigest()
            fname = f"{digest[:24]}.npy"
            path = self.root / fname
            if not path.exists():  # content-addressed: dedup identical leaves
                src = None if src_root is None else src_root / fname
                if src is not None and src.exists():
                    try:
                        os.link(src, path)
                    except OSError:
                        path.write_bytes(raw)
                else:
                    path.write_bytes(raw)
            entries.append({"file": fname, "sha256": digest})
        manifest = {"format": BLOB_FORMAT, "skeleton": skeleton,
                    "leaves": entries}
        (self.root / f"{name}.json").write_text(
            json.dumps(manifest, sort_keys=True, separators=(",", ":"))
        )
        return name

    def get(self, name: str) -> Any:
        mpath = self.root / f"{name}.json"
        if not mpath.exists():
            raise FileNotFoundError(f"blob manifest missing: {mpath}")
        try:
            manifest = json.loads(mpath.read_text())
        except ValueError as e:
            raise ValueError(f"blob manifest corrupt: {mpath}: {e}") from e
        fmt = manifest.get("format")
        if fmt != BLOB_FORMAT:
            raise ValueError(
                f"blob {mpath} has format {fmt!r}, expected {BLOB_FORMAT!r}"
            )
        leaves = []
        for entry in manifest["leaves"]:
            lpath = self.root / entry["file"]
            if not lpath.exists():
                raise FileNotFoundError(
                    f"blob {name!r} leaf missing: {lpath}"
                )
            raw = lpath.read_bytes()
            digest = hashlib.sha256(raw).hexdigest()
            if digest != entry["sha256"]:
                raise ValueError(
                    f"blob {name!r} leaf corrupt: {lpath} sha256 {digest} "
                    f"!= recorded {entry['sha256']}"
                )
            leaves.append(np.load(io.BytesIO(raw), allow_pickle=False))
        return _unflatten(manifest["skeleton"], leaves)

    def exists(self, name: str) -> bool:
        return (self.root / f"{name}.json").exists()


class CheckpointManager:
    """Ties a training client's checkpoints to the platform lifecycle.

    save(): write blob -> publish {step, blob} as a task result (buffered
    on the client's LocalDisk until the server confirms — the paper's
    §3.3.1 guarantee, so a crash between blob write and ack replays the
    publication, and a crash before blob write simply loses the step).

    latest(): read the task's acknowledged results from the server and
    return the newest checkpoint whose blob exists.
    """

    def __init__(self, blob_store: BlobStore, client, task_id: str):
        self.blobs = blob_store
        self.client = client  # EdgeClient of this pod-host
        self.task_id = task_id

    def save(self, step: int, state: Any) -> str:
        name = f"{self.task_id}-step{step:08d}"
        self.blobs.put(name, state)
        # Publish through the sync loop: result -> dirty/submit path.
        self.client._on_container_event(
            self.task_id, result_value={"kind": "checkpoint", "step": step, "blob": name}
        )
        self.client.run_until_idle()
        return name

    def latest(self, server) -> tuple[int, Any] | None:
        results = server.results(self.task_id)
        best: tuple[int, str] | None = None
        for r in results:
            v = r.value
            if isinstance(v, dict) and v.get("kind") == "checkpoint":
                if self.blobs.exists(v["blob"]):
                    if best is None or v["step"] > best[0]:
                        best = (v["step"], v["blob"])
        if best is None:
            return None
        return best[0], self.blobs.get(best[1])
