"""Checkpoint/restart THROUGH the AutoSPADA control plane.

This is the paper's resiliency mechanism applied to training (DESIGN.md
§2): a training job is an *assignment*; each pod-host is a platform
*client*; a checkpoint is an *intermediate result* that is cached locally
until the server acknowledges it as recorded — after which the step is
durable. A restarted (preempted) pod fetches its state snapshot, reads the
latest acknowledged checkpoint id from the task's results, and resumes
from the matching blob.

Tensor payloads live in a blob store (filesystem here; GCS/S3 in a real
deployment) — only metadata + logical clocks flow through the document
store, the same split the paper makes between MongoDB documents and bulk
results.
"""
from __future__ import annotations

import json
import pickle
from pathlib import Path
from typing import Any

import jax
import numpy as np


class BlobStore:
    """Content-addressed tensor blobs on disk."""

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)

    def put(self, name: str, tree: Any) -> str:
        leaves, treedef = jax.tree.flatten(tree)
        path = self.root / f"{name}.npz"
        np.savez(
            path, **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
        )
        (self.root / f"{name}.treedef.pkl").write_bytes(pickle.dumps(treedef))
        return name

    def get(self, name: str) -> Any:
        data = np.load(self.root / f"{name}.npz")
        treedef = pickle.loads(
            (self.root / f"{name}.treedef.pkl").read_bytes()
        )
        leaves = [data[f"leaf_{i}"] for i in range(len(data.files))]
        return jax.tree.unflatten(treedef, leaves)

    def exists(self, name: str) -> bool:
        return (self.root / f"{name}.npz").exists()


class CheckpointManager:
    """Ties a training client's checkpoints to the platform lifecycle.

    save(): write blob -> publish {step, blob} as a task result (buffered
    on the client's LocalDisk until the server confirms — the paper's
    §3.3.1 guarantee, so a crash between blob write and ack replays the
    publication, and a crash before blob write simply loses the step).

    latest(): read the task's acknowledged results from the server and
    return the newest checkpoint whose blob exists.
    """

    def __init__(self, blob_store: BlobStore, client, task_id: str):
        self.blobs = blob_store
        self.client = client  # EdgeClient of this pod-host
        self.task_id = task_id

    def save(self, step: int, state: Any) -> str:
        name = f"{self.task_id}-step{step:08d}"
        self.blobs.put(name, state)
        # Publish through the sync loop: result -> dirty/submit path.
        self.client._on_container_event(
            self.task_id, result_value={"kind": "checkpoint", "step": step, "blob": name}
        )
        self.client.run_until_idle()
        return name

    def latest(self, server) -> tuple[int, Any] | None:
        results = server.results(self.task_id)
        best: tuple[int, str] | None = None
        for r in results:
            v = r.value
            if isinstance(v, dict) and v.get("kind") == "checkpoint":
                if self.blobs.exists(v["blob"]):
                    if best is None or v["step"] > best[0]:
                        best = (v["step"], v["blob"])
        if best is None:
            return None
        return best[0], self.blobs.get(best[1])
