"""Train step: loss -> grad -> AdamW, with optional gradient accumulation.

The step function is pure (state, batch) -> (state, metrics); pjit handles
distribution via the planner's in/out shardings. Remat lives inside the
model (per pattern-block `jax.checkpoint` around each scan body).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.model import ArchConfig, init_params, train_loss
from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state

TrainState = dict[str, Any]  # {"params": ..., "opt": ..., "step": scalar}


def init_train_state(
    cfg: ArchConfig, opt_cfg: OptimizerConfig, key: jax.Array
) -> TrainState:
    params = init_params(cfg, key)
    return {"params": params, "opt": init_opt_state(opt_cfg, params)}


def make_train_step(cfg: ArchConfig, opt_cfg: OptimizerConfig, microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    `microbatches > 1` splits the per-step batch on the leading axis and
    accumulates grads in f32 with a lax.scan (sequential microbatching —
    the standard trick when the global batch does not fit activations).
    """

    def loss_fn(params, batch):
        loss, metrics = train_loss(params, cfg, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single_grads(params, batch):
        (loss, metrics), grads = grad_fn(params, batch)
        return loss, metrics, grads

    def train_step(state: TrainState, batch: dict[str, jax.Array]):
        params = state["params"]
        if microbatches == 1:
            loss, metrics, grads = single_grads(params, batch)
        else:
            def reshape(x):
                return x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])

            mb = jax.tree.map(reshape, batch)

            def acc_step(carry, mbatch):
                acc, loss_acc = carry
                loss, _, grads = single_grads(params, mbatch)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads
                )
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, loss_sum), _ = jax.lax.scan(
                acc_step, (zeros, jnp.float32(0.0)), mb
            )
            grads = jax.tree.map(lambda g: (g / microbatches), gsum)
            loss = loss_sum / microbatches
            metrics = {"nll": loss, "moe_aux": jnp.float32(0.0)}

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, state["opt"]
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
