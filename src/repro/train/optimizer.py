"""Optimizers with memory-footprint control for 100B+ configs.

AdamW with configurable moment dtype: the 398B/141B models cannot hold
f32 moments + f32 master weights in 16 GB/chip HBM even fully sharded
(4.8 TB of optimizer state at 12 B/param). The production recipe used
here: bf16 stored params, bf16 moments, f32 update math per step
(cast up, update, cast down). The EXPERIMENTS.md memory table records the
per-device budget for every cell.

`adafactor` (factored second moment) is provided as the lower-memory
alternative for ablations.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

Params = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float | None = 1.0
    moment_dtype: str = "float32"  # bf16 for >=100B configs
    warmup_steps: int = 100
    kind: str = "adamw"  # adamw | adafactor

    @property
    def mdtype(self):
        return jnp.dtype(self.moment_dtype)


def init_opt_state(cfg: OptimizerConfig, params: Params) -> dict[str, Any]:
    if cfg.kind == "adamw":
        zeros = lambda p: jnp.zeros(p.shape, cfg.mdtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }
    if cfg.kind == "adafactor":
        def facto(p):
            if p.ndim < 2:
                return {"v": jnp.zeros(p.shape, jnp.float32)}
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {
            "f": jax.tree.map(facto, params),
            "step": jnp.zeros((), jnp.int32),
        }
    raise ValueError(cfg.kind)


def _lr_at(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    return cfg.learning_rate * warm


def clip_by_global_norm(grads: Params, max_norm: float) -> tuple[Params, jax.Array]:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def adamw_update(
    cfg: OptimizerConfig,
    params: Params,
    grads: Params,
    opt_state: dict[str, Any],
) -> tuple[Params, dict[str, Any], dict[str, jax.Array]]:
    step = opt_state["step"] + 1
    grad_norm = jnp.float32(0.0)
    if cfg.grad_clip_norm is not None:
        grads, grad_norm = clip_by_global_norm(grads, cfg.grad_clip_norm)
    lr = _lr_at(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(cfg.mdtype), v32.astype(cfg.mdtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": grad_norm, "lr": lr}
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
