from repro.train.optimizer import OptimizerConfig, adamw_update, init_opt_state
from repro.train.train_step import init_train_state, make_train_step

__all__ = [
    "OptimizerConfig", "adamw_update", "init_opt_state", "init_train_state",
    "make_train_step",
]
