"""Streaming fleet analytics — the paper's data-analytics case study
(fuel-consumption statistics over a driving fleet), on the columnar
signal plane.

A 64-vehicle mixed fleet (highway cruisers, urban stop-go, cold idlers —
seeded drive cycles from `repro.fleet.scenarios`) streams signals through
one `FleetSignalPlane`: a single jit step advances every vehicle's every
signal per simulation tick. Each analytics window is an ordinary platform
assignment: vehicles fold their recent `Vehicle.FuelRate` observations
through Welford's algorithm and a fixed-bin histogram *on-board* and
publish only the (count, mean, M2, bins) sketch; the server merges all
sketches in one batched jit reduction — exact fleet statistics, no raw
samples ever uploaded.

The run is deterministic in the seed, faults and all.

Run: PYTHONPATH=src python examples/fleet_analytics.py
"""
from repro.fleet import AnalyticsConfig, FleetSimulator, SimConfig


def main() -> None:
    sim = FleetSimulator(
        SimConfig(
            n_clients=64,
            seed=7,
            scenario="mixed",     # seeded drive-cycle mix per vehicle
            p_drop=0.05,          # lossy broker, as always
            max_delay=1,
            straggler_fraction=0.1,
        )
    )
    driver = sim.run_analytics(
        AnalyticsConfig(
            signal="Vehicle.FuelRate",
            window=48,            # on-vehicle samples per sketch
            bins=16,
            deadline_fraction=0.85,
            deadline_pumps=48,
        ),
        windows=6,
        warmup_ticks=24,          # let the signal history ring fill
    )
    print(sim.metrics.format_table())
    print(driver.format_table())
    last = driver.history[-1]
    print(
        f"fleet Vehicle.FuelRate: mean={last.mean:.3f} L/h, "
        f"std={last.std:.3f}, {last.count} on-board samples sketched by "
        f"{last.participants} vehicles — raw samples never left the cars"
    )


if __name__ == "__main__":
    main()
