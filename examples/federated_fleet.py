"""Federated learning over a 24-vehicle fleet with dropout, stragglers
and int8-compressed uploads — the paper's §8 distributed-learning use
case on the faithful platform implementation.

Every round is an assignment; vehicles drop out mid-round (ignition off);
the deadline cancels stragglers; the server aggregates whatever arrived.
Watch `dist_to_optimum` fall anyway.

Run: PYTHONPATH=src python examples/federated_fleet.py
"""
import numpy as np

from repro.core import User, make_platform
from repro.core.signals import constant
from repro.fleet import FedConfig, FederatedDriver, FleetPool


def main() -> None:
    store, broker, servers = make_platform(n_servers=2)
    server = servers[0]
    pool = FleetPool(
        store,
        broker,
        server,
        n_vehicles=24,
        signal_fn=lambda i: {"Vehicle.RoadGrade": constant(0.01 * (i % 5))},
    )
    user = User(server, broker)
    dim = 32
    driver = FederatedDriver(
        user,
        FedConfig(local_steps=4, local_lr=0.15, deadline_fraction=0.75),
        dim=dim,
        w_true=np.sin(np.linspace(0, 3, dim)).astype(np.float32),
    )
    print(f"{'round':>5} {'clients':>8} {'canceled':>9} {'client_loss':>12} {'dist':>8}")
    for rnd in range(8):
        rec = driver.run_round(rnd, pump=lambda: pool.pump(dropout_prob=0.04))
        print(
            f"{rec['round']:>5} {rec['participants']:>8} {rec['canceled']:>9} "
            f"{rec['mean_client_loss']:>12.4f} {rec['dist_to_optimum']:>8.4f}"
        )
    first, last = driver.history[0], driver.history[-1]
    assert last["dist_to_optimum"] < first["dist_to_optimum"]
    print("converged despite dropout + stragglers — OK")


if __name__ == "__main__":
    main()
