"""Federated learning over a simulated vehicle fleet — the paper's §8
distributed-learning use case, driven by the discrete-event simulator.

A 128-vehicle fleet trains under everything the paper says real fleets do
to you at once: a lossy broker (seeded drop/duplicate/delay schedule),
ignition churn (vehicles power off mid-round and return), and stragglers
that miss deadlines and get canceled. Every round is an assignment;
uploads are int8-quantized; the server aggregates whatever arrived by the
deadline in a single batched dequant+weighted-sum. Watch
`dist_to_optimum` fall anyway.

The whole run is deterministic in the seed — rerun it and the final
aggregate checksum is identical, faults and all.

Run: PYTHONPATH=src python examples/federated_fleet.py
"""
import numpy as np

from repro.fleet import FedConfig, FleetSimulator, SimConfig


def main() -> None:
    sim = FleetSimulator(
        SimConfig(
            n_clients=128,
            seed=42,
            p_drop=0.1,        # 10% of clock notifications vanish
            p_duplicate=0.05,  # 5% of QoS-1 deliveries repeat
            max_delay=2,       # up to 2 ticks of delivery delay
            p_leave=0.002,     # ignition off mid-anything
            p_return=0.2,      # ...and back soon after
            straggler_fraction=0.15,
        )
    )
    driver = sim.run_federated(
        FedConfig(
            local_steps=4,
            local_lr=0.15,
            deadline_fraction=0.75,
            deadline_pumps=48,
        ),
        dim=32,
        rounds=8,
    )
    print(sim.metrics.format_table())
    first, last = driver.history[0], driver.history[-1]
    assert last["dist_to_optimum"] < first["dist_to_optimum"]
    print(f"aggregate checksum: {float(np.sum(driver.w)):.6f}")
    print("converged despite drops, churn and stragglers — OK")


if __name__ == "__main__":
    main()
