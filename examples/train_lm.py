"""End-to-end LM training through the AutoSPADA control plane — with a
mid-run preemption that the platform survives.

A ~25M-param gemma3-family model trains for 300 steps on the synthetic
pipeline. At step 180 the pod is "preempted" (process state lost). A new
TrainRun over the same LocalDisk + platform resumes from the last
*acknowledged* checkpoint and finishes. The loss curve is continuous.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_tiny
from repro.launch.train import Preempted, TrainRun


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--workdir", default="experiments/train_lm")
    args = ap.parse_args()
    preempt_at = int(args.steps * 0.6)

    # ~25M params: widen the tiny gemma3 config
    base = get_tiny("gemma3-1b")
    cfg = dataclasses.replace(
        base,
        name="gemma3-25m",
        d_model=384,
        n_heads=4,
        n_kv_heads=1,
        head_dim=96,
        d_ff=1536,
        vocab_size=8192,
        groups=((base.groups[0][0], 2), (base.groups[1][0], 1)),  # 14 layers
    )
    shapes = jax.eval_shape(
        lambda k: __import__("repro.models", fromlist=["init_params"]).init_params(cfg, k),
        jax.random.PRNGKey(0),
    )
    n = sum(x.size for x in jax.tree.leaves(shapes))
    print(f"model: {cfg.name}  {n/1e6:.1f}M params, {cfg.n_layers} layers")

    run = TrainRun(
        "gemma3-1b", tiny=True, batch=args.batch, seq=args.seq,
        workdir=args.workdir,
    )
    run.cfg = cfg  # widened variant
    run._step_fn = None
    print(f"training to {args.steps} steps, preemption at {preempt_at} ...")
    try:
        run.run(args.steps, ckpt_every=30, log_every=20, preempt_at=preempt_at)
        raise AssertionError("expected a preemption")
    except Preempted as e:
        print(f"!! pod preempted at step {e.step} — volatile state lost")
    run.host.shutdown()

    run2 = TrainRun(
        "gemma3-1b", tiny=True, batch=args.batch, seq=args.seq,
        workdir=args.workdir,
        platform=(run.store, run.broker, run.server),
        disk=run.disk, task_id=run.task_id,
    )
    run2.cfg = cfg
    run2._step_fn = None
    _, start = run2.init_or_restore()
    print(f"restart: resuming from last acknowledged checkpoint (step {start})")
    logs = run2.run(args.steps, ckpt_every=30, log_every=20)
    print(f"{'step':>6} {'loss':>8}")
    for rec in logs:
        print(f"{rec['step']:>6} {rec['loss']:>8.4f}")
    first, last = logs[0]["loss"], logs[-1]["loss"]
    assert last < first, "loss should decrease"
    print(f"loss {first:.3f} -> {last:.3f} across a preemption — OK")


if __name__ == "__main__":
    main()
