"""Batched serving demo: prefill + KV-cache decode with the continuous-
batching scheduler, on a reduced qwen3-family model.

Run: PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import numpy as np

from repro.configs import get_tiny
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine, serve_loop


def main() -> None:
    cfg = get_tiny("qwen3-4b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, cache_len=128)
    rng = np.random.default_rng(0)

    requests = [
        Request(
            request_id=f"req-{i}",
            prompt=rng.integers(0, cfg.vocab_size, (int(l),)),
            max_new_tokens=12,
        )
        for i, l in enumerate([16, 24, 32, 16, 48, 24, 16, 32])
    ]
    t0 = time.perf_counter()
    results = serve_loop(engine, requests, batch_size=4)
    dt = time.perf_counter() - t0
    total_new = sum(len(v) for v in results.values())
    print(f"{len(requests)} requests, {total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s on CPU)")
    for rid in sorted(results):
        print(f"{rid}: {results[rid]}")
    assert all(r.done for r in requests)
    print("OK")


if __name__ == "__main__":
    main()
