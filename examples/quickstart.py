"""Quickstart: the paper's §5 workflow, end to end, in one file.

1. spin up the platform (store + broker + stateless server);
2. boot two simulated vehicles (sync loop, signal broker);
3. test the payload locally first with the dummy library (paper §5.1.1);
4. commit a "mean speed" assignment (paper Listing 1 / §5.2.1);
5. stream the results back with method chaining:
   ``assign.commit().await_results(...)``.

Run: PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    EdgeClient,
    ScriptedSignalBroker,
    User,
    dummy_context,
    make_platform,
    run_inline,
)
from repro.core.signals import constant

MEAN_SPEED_PAYLOAD = """
import autospada

params = autospada.get_parameters()
n, signal = params["seconds"], params["signal_name"]
total = 0.0
for i in range(n):
    v = autospada.get_signal(signal)
    total += v if v is not None else 0.0
autospada.publish({"mean_speed": total / n})
"""


def main() -> None:
    # --- §5.1.1: test the payload locally, no platform needed ---------- #
    print("== local dummy-library test ==")
    exit = run_inline(
        MEAN_SPEED_PAYLOAD,
        dummy_context(seed=0, parameters={"seconds": 3, "signal_name": "X"}),
    )
    print(f"local run: exit_code={exit.exit_code}\n{exit.log}")

    # --- platform + fleet ---------------------------------------------- #
    store, broker, (server,) = make_platform()
    vehicles = []
    for i, speed in enumerate((63.0, 87.0)):
        sig = ScriptedSignalBroker({"Vehicle.Speed": constant(speed)})
        c = EdgeClient(f"veh-{i}", server, broker, signal_broker=sig)
        c.bootstrap()
        c.run_until_idle()
        vehicles.append((c, sig))

    def pump():
        for c, sig in vehicles:
            sig.tick()
            c.run_until_idle()

    # --- §5.2.1 user workflow ------------------------------------------ #
    user = User(server, broker)
    payload = user.payload(MEAN_SPEED_PAYLOAD, name="mean-speed")
    parameters = user.parameter(
        {"seconds": 5, "signal_name": "Vehicle.Speed"}
    )
    tasks = [
        user.task(client_id, payload, parameters)
        for client_id in user.online_clients()
    ]
    assign = user.assignment("Mean speed", tasks)
    results = assign.commit().await_results(pump)

    print("== results ==")
    for task_id, values in results.items():
        print(f"{task_id}: {values}")
    print("statuses:", assign.statuses())
    assert {v[0]["mean_speed"] for v in results.values()} == {63.0, 87.0}
    print("OK")


if __name__ == "__main__":
    main()
