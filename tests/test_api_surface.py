"""`autospada.__all__` is a frozen contract (paper §5.1).

Payload code may rely on exactly the names in `AUTOSPADA_API` — in every
execution mode, in every release. This file is the drift tripwire: the
expected tuple is duplicated here on purpose, so any edit to the contract
has to be made twice, loudly, in the same diff.
"""
import io
from contextlib import redirect_stdout

from repro.core import PayloadContext, dummy_context, run_inline
from repro.core.payload_api import AUTOSPADA_API

# Deliberately NOT imported from the source module: growing or shrinking
# the payload surface must fail here until this pin is updated too.
EXPECTED_API = (
    "get_signal",
    "get_signal_window",
    "get_signal_sketch",
    "publish",
    "get_parameters",
    "cache_state",
    "load_state",
    "clear_state",
    "sleep",
    "time",
)


def test_contract_tuple_is_pinned():
    assert AUTOSPADA_API == EXPECTED_API
    assert isinstance(AUTOSPADA_API, tuple)  # immutable on purpose
    assert len(set(AUTOSPADA_API)) == len(AUTOSPADA_API)


def test_every_contract_name_is_a_documented_method():
    for name in AUTOSPADA_API:
        fn = getattr(PayloadContext, name)
        assert callable(fn), name
        assert fn.__doc__ and fn.__doc__.strip(), f"{name} is undocumented"


def test_no_unadvertised_public_surface():
    """Public methods beyond the contract would be de-facto API the tuple
    doesn't admit to. `cancel` is the one sanctioned exception: it is the
    host-side control edge (the `docker stop` analogue), not something
    payload code should ever call on itself."""
    public = {
        n for n in vars(PayloadContext)
        if not n.startswith("_") and callable(getattr(PayloadContext, n))
    }
    assert public == set(AUTOSPADA_API) | {"cancel"}


def test_dunder_all_matches_everywhere():
    import repro.core.payload_api as mod

    assert PayloadContext.__all__ == AUTOSPADA_API
    assert "AUTOSPADA_API" in mod.__all__
    ctx = dummy_context(seed=0)
    assert ctx.__all__ == AUTOSPADA_API  # instances advertise it too


def test_payloads_can_introspect_the_contract():
    """Inside the sandbox `import autospada` binds the context object, so
    the conventional `__all__` probe enumerates the frozen tuple."""
    src = (
        "import autospada\n"
        "autospada.publish(list(autospada.__all__))\n"
        "autospada.publish([callable(getattr(autospada, n))"
        " for n in autospada.__all__])\n"
    )
    seen = []
    ctx = PayloadContext(get_signal=lambda name: 0.0, publish=seen.append)
    exit_ = run_inline(src, ctx)
    assert exit_.ok, exit_.log
    assert seen[0] == list(AUTOSPADA_API)
    assert all(seen[1])


def test_dummy_context_implements_the_whole_contract():
    ctx = dummy_context(seed=7, parameters={"lr": 0.1})
    with redirect_stdout(io.StringIO()):
        for name in AUTOSPADA_API:
            if name == "get_signal":
                assert isinstance(ctx.get_signal("Vehicle.Speed"), float)
            elif name == "get_signal_window":
                assert len(ctx.get_signal_window("Vehicle.Speed", 4)) == 4
            elif name == "get_signal_sketch":
                sk = ctx.get_signal_sketch("Vehicle.Speed", 8)
                assert sk["count"] == 8
                assert len(sk["hist"]) == 16 and sum(sk["hist"]) == 8
                assert len(sk["qsk"]) == 32
                assert sorted(sk["qsk"]) == sk["qsk"]
            elif name == "publish":
                ctx.publish({"ok": True})
            elif name == "get_parameters":
                assert ctx.get_parameters() == {"lr": 0.1}
            elif name == "cache_state":
                ctx.cache_state({"step": 3})
            elif name == "load_state":
                assert ctx.load_state() == {"step": 3}
            elif name == "clear_state":
                ctx.clear_state()
                assert ctx.load_state() is None
            elif name == "sleep":
                ctx.sleep(0.0)
            elif name == "time":
                assert isinstance(ctx.time(), float)
