"""Device-sharded signal plane: bit-for-bit parity with the single-host
plane (values, windows, offline NaN masks) at N=1024, shard-aware
geometric growth, simulator integration, and a hypothesis property test
over random fleets. Runs on any device count — the CI `multi-device`
lane runs it under XLA_FLAGS=--xla_force_host_platform_device_count=8 so
the shards genuinely span devices."""
import jax
import numpy as np
import pytest

from repro.core.plane_sharded import ShardedSignalPlane
from repro.core.signals import FleetSignalPlane, SignalHandler
from repro.fleet import FedConfig, FleetSimulator, SimConfig
from repro.fleet.scenarios import SIGNALS, Scenario, build_plane
from repro.sharding import fleet as fleet_sharding

NDEV = len(jax.devices())


def _pair(name="mixed", n=8, seed=13, history=32):
    scen = Scenario(name, seed=seed)
    return scen.plane(n, history=history), scen.sharded_plane(n, history=history)


# --------------------------------------------------------------------- #
# the tentpole contract: sharded == host, bit for bit                    #
# --------------------------------------------------------------------- #
def test_sharded_plane_matches_host_plane_at_fleet_scale():
    """N=1024 over every available device (8 in the CI multi-device
    lane): values matrix, reads, and history windows are identical after
    dozens of ticks."""
    host, sharded = _pair(n=1024, history=16)
    assert sharded._capacity % NDEV == 0
    for _ in range(20):
        host.step()
        sharded.step()
    assert np.array_equal(host.values, sharded.values)
    for row in (0, 1, 500, 1023):
        for sig in SIGNALS:
            assert host.read(row, sig) == sharded.read(row, sig)
            assert host.window(row, sig, 12) == sharded.window(row, sig, 12)


def test_sharded_step_spans_every_device():
    _, sharded = _pair(n=64)
    sharded.step()
    assert sharded.devices == NDEV
    assert len(sharded._dvalues.sharding.device_set) == NDEV
    assert len(sharded._dhist.sharding.device_set) == NDEV


def test_offline_nan_masking_matches_host_plane():
    """Ring masking parity: a powered-off row's window after re-ignition
    only shows powered-on observations, exactly like the host plane."""
    host, sharded = _pair(n=6, history=64)
    for p in (host, sharded):
        for _ in range(3):
            p.step()
        p.set_online(2, False)
        for _ in range(4):
            p.step()
        p.set_online(2, True)
        for _ in range(2):
            p.step()
    for row in range(6):
        for sig in SIGNALS:
            assert host.window(row, sig, 64) == sharded.window(row, sig, 64)
    # values keep advancing fleet-globally on both planes
    assert np.array_equal(host.values, sharded.values)


def test_plane_signal_view_and_handler_work_unchanged():
    """`autospada.get_signal` / `get_signal_window` plumbing: the same
    SignalHandler-over-PlaneSignalView stack reads the sharded plane."""
    host, sharded = _pair(n=5)
    hh = [SignalHandler(host.view(i)) for i in range(5)]
    hs = [SignalHandler(sharded.view(i)) for i in range(5)]
    for _ in range(7):
        host.step()
        sharded.step()
        for i in range(5):
            for sig in SIGNALS:
                assert hh[i].get(sig) == hs[i].get(sig)
                assert hh[i].window(sig, 4) == hs[i].window(sig, 4)
    assert hs[0].get("Vehicle.DoesNotExist") is None
    assert hs[0].window("Vehicle.DoesNotExist", 4) == []


# --------------------------------------------------------------------- #
# shard-aware growth                                                     #
# --------------------------------------------------------------------- #
def test_capacity_is_always_a_device_count_multiple():
    _, sharded = _pair(n=3)
    assert sharded._capacity % NDEV == 0 and sharded._capacity >= 3
    for _ in range(2 * NDEV + 3):
        sharded.add_client()
    assert sharded._capacity % NDEV == 0
    assert sharded._capacity >= sharded.n_clients


def test_growth_parity_with_host_plane():
    host, sharded = _pair(n=4, history=16)
    host.step()
    sharded.step()
    before = host.values.copy()
    for _ in range(9):
        assert host.add_client() == sharded.add_client()
    # row stability: regrowth recomputed the same tick — old rows intact
    assert np.array_equal(sharded.values[:4], before)
    host.step()
    sharded.step()
    assert host.n_clients == sharded.n_clients == 13
    assert np.array_equal(host.values, sharded.values)
    for row in range(13):
        assert host.window(row, "Vehicle.Speed", 16) == sharded.window(
            row, "Vehicle.Speed", 16
        )
    # a freshly-joined row's history starts at its join tick, not before
    assert len(sharded.window(12, "Vehicle.Speed", 16)) == 2


def test_growth_never_doubles_per_join():
    """Geometric growth survives the sharded layout: N single joins
    recompile the tick O(log N) times, not N times."""
    scen = Scenario("urban", seed=1)
    calls = []

    def counting_builder(cap):
        calls.append(cap)
        return scen.step_fn(cap)

    plane = ShardedSignalPlane(SIGNALS, 4, counting_builder, history=16)
    for _ in range(28):
        plane.add_client()
    assert plane.n_clients == 32
    # initial compile + O(log N) regrows (exact count depends on rounding)
    assert len(calls) <= 6


def test_spare_capacity_rows_fail_fast():
    _, sharded = _pair(n=3)
    sharded.step()
    if sharded._capacity == sharded.n_clients:
        sharded.add_client()  # force spare rows on 1-device meshes
        sharded.step()
    assert sharded._capacity > sharded.n_clients
    for bad in (sharded.n_clients, sharded._capacity - 1, -1):
        with pytest.raises(IndexError, match="out of range"):
            sharded.read(bad, SIGNALS[0])
        with pytest.raises(IndexError, match="out of range"):
            sharded.window(bad, SIGNALS[0], 4)
        with pytest.raises(IndexError, match="out of range"):
            sharded.view(bad)
        with pytest.raises(IndexError, match="out of range"):
            sharded.set_online(bad, False)


def test_traces_stay_on_the_host_plane():
    with pytest.raises(NotImplementedError, match="scenario-backed"):
        ShardedSignalPlane.from_trace(SIGNALS, np.zeros((1, 2, 4)))


# --------------------------------------------------------------------- #
# CSV playback: streamed host rows fed into the sharded ring             #
# --------------------------------------------------------------------- #
_CSVS = [
    "a,b\n1,2\n,3\n4,\n7,8\n",   # blanks hold the previous value
    "a,c\n5,\n,9\n",             # short trace: holds its last row
    "b\n\n6\n",                  # blank line, late first observation
]


def test_sharded_csv_plane_matches_host_plane_bit_for_bit():
    host = FleetSignalPlane.from_csv_fleet(_CSVS)
    shard = ShardedSignalPlane.from_csv_fleet(_CSVS)
    assert shard.names == host.names
    assert shard.n_clients == host.n_clients == len(_CSVS)
    shard.set_online(1, False)
    host.set_online(1, False)
    for t in range(6):  # runs past the longest trace (4 ticks)
        for i in range(host.n_clients):
            for name in host.names:
                assert shard.read(i, name) == host.read(i, name), (t, i, name)
                assert shard.window(i, name, 5) == host.window(i, name, 5)
        if t == 2:
            shard.set_online(1, True)
            host.set_online(1, True)
        host.step()
        shard.step()
    assert np.array_equal(shard.values, host.values, equal_nan=True)


def test_sharded_csv_plane_is_fixed_size():
    shard = ShardedSignalPlane.from_csv_fleet(["a\n1\n2\n"])
    with pytest.raises(ValueError, match="fixed fleet size"):
        shard.add_client()


def test_build_plane_selects_and_rejects():
    assert isinstance(build_plane("mixed", 4, plane="sharded"), ShardedSignalPlane)
    assert not isinstance(build_plane("mixed", 4, plane="host"), ShardedSignalPlane)
    with pytest.raises(ValueError, match="unknown plane"):
        build_plane("mixed", 4, plane="columnar")


def test_round_up_clients():
    mesh = fleet_sharding.client_mesh()
    d = fleet_sharding.device_count(mesh)
    assert fleet_sharding.round_up_clients(1, mesh) == d
    assert fleet_sharding.round_up_clients(d, mesh) == d
    assert fleet_sharding.round_up_clients(d + 1, mesh) == 2 * d
    assert fleet_sharding.round_up_clients(7 * d, mesh) == 7 * d


# --------------------------------------------------------------------- #
# simulator integration                                                  #
# --------------------------------------------------------------------- #
def test_simulator_runs_identically_on_the_sharded_plane():
    """Same SimConfig through both planes: identical final aggregate and
    broker counters — the sharded plane is payload-invisible."""

    def run(plane):
        sim = FleetSimulator(
            SimConfig(
                n_clients=12, seed=21, scenario="mixed", p_drop=0.1,
                max_delay=1, plane=plane,
            )
        )
        drv = sim.run_federated(
            FedConfig(
                local_steps=2, local_lr=0.2, deadline_fraction=0.8,
                deadline_pumps=32,
            ),
            dim=8,
            rounds=2,
            n_samples=8,
        )
        counters = (
            sim.broker.published, sim.broker.delivered, sim.broker.dropped
        )
        return drv.w.copy(), sim.plane.values.copy(), counters

    w_h, v_h, c_h = run("host")
    w_s, v_s, c_s = run("sharded")
    assert np.array_equal(w_h, w_s)
    assert np.array_equal(v_h, v_s)
    assert c_h == c_s


def test_simulator_reignition_window_on_sharded_plane():
    sim = FleetSimulator(
        SimConfig(n_clients=2, seed=0, scenario="mixed", plane="sharded")
    )
    cid = "veh-001"
    for _ in range(4):
        sim.tick()
    sim.pool.power_off(cid)
    for _ in range(3):
        sim.tick()
    sim.pool.power_on(cid)
    sim.pool.vehicles[cid].client.run_until_idle()
    for _ in range(2):
        sim.tick()
    churned = sim.pool.vehicles[cid].client.signal_handler.window(
        "Vehicle.Speed", 64
    )
    assert len(churned) == 7  # 3 ignition-off ticks are not "observed"


# --------------------------------------------------------------------- #
# property test: random fleets, growth, and power patterns               #
# --------------------------------------------------------------------- #
def test_property_random_growth_and_power_patterns_match():
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    ops = st.lists(
        st.one_of(
            st.just(("step",)),
            st.just(("join",)),
            st.tuples(st.just("power"), st.integers(0, 31), st.booleans()),
        ),
        min_size=1,
        max_size=12,
    )

    @settings(
        max_examples=15,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(n=st.integers(1, 9), seed=st.integers(0, 3), script=ops)
    def check(n, seed, script):
        scen = Scenario("mixed", seed=seed)
        host = scen.plane(n, history=8)
        sharded = scen.sharded_plane(n, history=8)
        for op in script:
            if op[0] == "step":
                host.step()
                sharded.step()
            elif op[0] == "join":
                assert host.add_client() == sharded.add_client()
            else:
                _, row, online = op
                row %= host.n_clients
                host.set_online(row, online)
                sharded.set_online(row, online)
        assert np.array_equal(host.values, sharded.values)
        for row in range(host.n_clients):
            for sig in ("Vehicle.Speed", "Vehicle.FuelRate"):
                assert host.read(row, sig) == sharded.read(row, sig)
                assert host.window(row, sig, 8) == sharded.window(row, sig, 8)

    check()
