"""Serve engine: batched generation + continuous-batching scheduler."""
import numpy as np
import jax

from repro.configs import get_tiny
from repro.models import init_params
from repro.serve.engine import Request, ServeEngine, serve_loop


def test_generate_batched_deterministic():
    cfg = get_tiny("granite-8b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, cache_len=64)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab_size, (3, 16))
    a = eng.generate(prompts, max_new_tokens=8)
    b = eng.generate(prompts, max_new_tokens=8)
    assert a.shape == (3, 8)
    assert np.array_equal(a, b)  # greedy is deterministic
    assert (a < cfg.vocab_size).all()


def test_serve_loop_handles_mixed_requests():
    cfg = get_tiny("qwen3-4b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    eng = ServeEngine(cfg, params, cache_len=64)
    rng = np.random.default_rng(1)
    reqs = [
        Request(f"r{i}", rng.integers(0, cfg.vocab_size, (int(l),)), max_new_tokens=4)
        for i, l in enumerate([8, 12, 16, 8, 12])
    ]
    results = serve_loop(eng, reqs, batch_size=2)
    assert set(results) == {f"r{i}" for i in range(5)}
    assert all(len(v) == 4 for v in results.values())
    assert all(r.done for r in reqs)
