"""Per-architecture smoke + numerical consistency tests (deliverable f).

Every assigned architecture instantiates its reduced config, runs one
forward/train step on CPU (shapes + finiteness), and — the strong check —
verifies that decode-with-cache reproduces teacher-forced prefill logits,
which exercises RoPE positions, cache layouts, rolling windows, SSM/mLSTM
recurrent states, and MoE decode in one assertion.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_tiny
from repro.models import (
    decode_step,
    init_params,
    param_count,
    prefill,
    train_loss,
)

B, S = 2, 64
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=B, S=S, train=True):
    if cfg.uses_embedding_input:
        out = {"frame_embeds": jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)}
        if train:
            out["labels"] = jax.random.randint(
                KEY, (B, S, cfg.n_codebooks), 0, cfg.vocab_size
            )
        return out
    if cfg.frontend == "vit_stub":
        P = cfg.n_patches
        out = {
            "patch_embeds": jax.random.normal(KEY, (B, P, cfg.d_model), jnp.float32),
            "tokens": jax.random.randint(KEY, (B, S - P), 0, cfg.vocab_size),
        }
        if train:
            out["labels"] = jnp.concatenate(
                [
                    jnp.full((B, P), -1, jnp.int32),
                    jax.random.randint(KEY, (B, S - P), 0, cfg.vocab_size),
                ],
                axis=1,
            )
        return out
    out = {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    if train:
        out["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return out


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_tiny(arch)
    params = init_params(cfg, KEY)
    assert param_count(params) > 0
    loss, metrics = jax.jit(lambda p, b: train_loss(p, cfg, b))(
        params, make_batch(cfg)
    )
    assert np.isfinite(float(loss)), (arch, loss)
    # grads flow and are finite
    g = jax.grad(lambda p: train_loss(p, cfg, make_batch(cfg))[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(x))) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_prefill(arch):
    """decode(cache after S tokens) == prefill(S+1 tokens) last logits."""
    cfg = get_tiny(arch)
    params = init_params(cfg, KEY)
    full = make_batch(cfg, S=S + 1, train=False)
    if cfg.uses_embedding_input:
        prompt = {"frame_embeds": full["frame_embeds"][:, :S]}
        step_in = {"frame_embeds": full["frame_embeds"][:, S:]}
    elif cfg.frontend == "vit_stub":
        prompt = {
            "patch_embeds": full["patch_embeds"],
            "tokens": full["tokens"][:, : S - cfg.n_patches],
        }
        step_in = {"tokens": full["tokens"][:, S - cfg.n_patches : S - cfg.n_patches + 1]}
    else:
        prompt = {"tokens": full["tokens"][:, :S]}
        step_in = {"tokens": full["tokens"][:, S : S + 1]}
    logits_ref, _ = jax.jit(
        lambda p, b: prefill(p, cfg, b, cache_len=S + 8)
    )(params, full if cfg.frontend != "vit_stub" else {
        "patch_embeds": full["patch_embeds"],
        "tokens": full["tokens"][:, : S + 1 - cfg.n_patches],
    })
    _, cache = jax.jit(lambda p, b: prefill(p, cfg, b, cache_len=S + 8))(
        params, prompt
    )
    logits_dec, _ = jax.jit(lambda p, b, c: decode_step(p, cfg, b, c))(
        params, step_in, cache
    )
    a = np.asarray(logits_ref, np.float32).reshape(B, -1)
    b = np.asarray(logits_dec, np.float32).reshape(B, -1)
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-6)
    assert err < 5e-2, f"{arch}: decode/prefill rel err {err:.3e}"


def test_loss_masking_ignores_minus_one():
    cfg = get_tiny("granite-8b")
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)
    l1, _ = train_loss(params, cfg, batch)
    batch2 = dict(batch)
    # masking half the labels changes the mean only via the mask
    batch2["labels"] = batch["labels"].at[:, ::2].set(-1)
    l2, _ = train_loss(params, cfg, batch2)
    assert np.isfinite(float(l2)) and abs(float(l1) - float(l2)) < 1.0


def test_vocab_padding_masks_padded_logits():
    """granite-moe's 49155 vocab pads to 49280; padded ids must never win."""
    cfg = get_tiny("granite-moe-1b-a400m")
    assert cfg.padded_vocab % 128 == 0
    params = init_params(cfg, KEY)
    _, cache = prefill(params, cfg, make_batch(cfg, train=False), cache_len=S + 4)
    logits, _ = decode_step(
        params, cfg, {"tokens": jnp.zeros((B, 1), jnp.int32)}, cache
    )
    top = int(jnp.argmax(logits[0, -1]))
    assert top < cfg.vocab_size


def test_full_configs_match_published_sizes():
    """Total/active params within 5% of the published figures."""
    expected = {
        "jamba-1.5-large-398b": (398e9, 94e9),
        "mixtral-8x22b": (141e9, 39e9),
        "granite-moe-1b-a400m": (1.3e9, 0.4e9),
        "granite-8b": (8e9, 8e9),
        "qwen3-4b": (4e9, 4e9),
        "gemma3-1b": (1.0e9, 1.0e9),
        "xlstm-1.3b": (1.3e9, 1.3e9),
    }
    for arch, (tot_e, act_e) in expected.items():
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda k, c=cfg: init_params(c, k), jax.random.PRNGKey(0)
        )
        tot = sum(x.size for x in jax.tree.leaves(shapes))
        assert abs(tot - tot_e) / tot_e < 0.08, (arch, tot)
