"""The analyst gateway's determinism contract (ROADMAP item 5).

* replay: same seed + same request trace -> byte-identical
  `GatewayResponse.encode()` streams, submissions included
* reads never perturb the world: a simulator serving a read-heavy trace
  stays bit-identical to an untouched twin
* interleaved sessions see the answers a lone session would (serial
  oracle), and statistics answers match an independent numpy merge of
  per-vehicle `sketch_reference` folds
* progress queries observe an in-flight federated round
* `admit_per_tick` backpressure turns overload into deterministic
  queueing delay
* bad requests answer ok=False instead of crashing the world
* host and client-sharded planes serve identical statistics bodies

Runs in the tier-1 lane and again in CI's 8-device lane (XLA_FLAGS
--xla_force_host_platform_device_count=8), where the sharded-plane
parity case exercises a real multi-device layout.
"""
import numpy as np
import pytest

from repro.fleet.simulator import Backends, FleetSimulator, SimConfig
from repro.kernels.sketch import SketchSpec, sketch_reference
from repro.serve import FleetGateway

SIGNAL = "Vehicle.FuelRate"
WINDOW = 16


def make_sim(n=48, seed=7, plane="host", **kw):
    cfg = SimConfig(
        n_clients=n,
        seed=seed,
        scenario="mixed",
        signal_history=32,
        backends=Backends(plane=plane),
        **kw,
    )
    sim = FleetSimulator(cfg)
    for _ in range(WINDOW + 2):  # fill the window the queries read
        sim.tick()
    return sim


def drive_mixed_trace(gw):
    """A two-session trace with reads and submissions in flight at once."""
    a, b = gw.session("ana"), gw.session("bob")
    a.gauges()
    b.fleet_stats(SIGNAL, window=WINDOW)
    a.quantile(SIGNAL, 0.9, window=WINDOW)
    a.submit_round(dim=8, n_samples=4)
    b.submit_window(SIGNAL, window=WINDOW, sketch=True)
    gw.tick()
    b.window(3, SIGNAL, 5)
    a.platform()
    gw.run_until_idle()
    out = [r for s in gw._sessions.values() for r in s.inbox]
    out.sort(key=lambda r: r.seq)
    return out


# --------------------------------------------------------------------- #
# replay + purity                                                       #
# --------------------------------------------------------------------- #
def test_replay_is_byte_identical():
    """The acceptance bar: twin worlds, same trace -> same bytes."""
    runs = []
    for _ in range(2):
        gw = FleetGateway(make_sim())
        runs.append([r.encode() for r in drive_mixed_trace(gw)])
    assert runs[0] == runs[1]
    assert all(isinstance(b, bytes) for b in runs[0])


def test_reads_do_not_perturb_the_world():
    """A read-heavy trace leaves the simulator bit-identical to a twin
    that ticked the same number of times with no gateway at all."""
    sim, twin = make_sim(), make_sim()
    gw = FleetGateway(sim)
    sess = gw.session("ana")
    for k in range(6):
        sess.gauges()
        sess.quantile(SIGNAL, 0.5, window=WINDOW)
        sess.window(k % sim.cfg.n_clients, SIGNAL, 4)
        gw.tick()
        twin.tick()
    gw.run_until_idle()
    while twin.t < sim.t:
        twin.tick()

    assert sim.t == twin.t
    assert sim.metrics.fleet_gauges() == twin.metrics.fleet_gauges()
    b1, b2 = sim.broker, twin.broker
    assert (b1.published, b1.delivered, b1.dropped) == (
        b2.published, b2.delivered, b2.dropped
    )
    spec = SketchSpec(window=WINDOW)
    s1 = sim.plane.fleet_sketch(SIGNAL, spec)
    s2 = twin.plane.fleet_sketch(SIGNAL, spec)
    np.testing.assert_array_equal(s1.counts, s2.counts)
    np.testing.assert_array_equal(s1.means, s2.means)
    np.testing.assert_array_equal(s1.hists, s2.hists)
    np.testing.assert_array_equal(s1.qvals, s2.qvals)
    for row in range(sim.cfg.n_clients):
        assert sim.plane.window(row, SIGNAL, 8) == twin.plane.window(
            row, SIGNAL, 8
        )


def test_interleaved_sessions_match_serial_oracle():
    """Three sessions racing reads get exactly the bodies one lone
    session sees at the same boundaries in a twin world."""
    gw = FleetGateway(make_sim())
    sessions = [gw.session(f"s{i}") for i in range(3)]
    for i, s in enumerate(sessions):  # interleaved arrival order
        s.fleet_stats(SIGNAL, window=WINDOW)
        s.quantile(SIGNAL, 0.75, window=WINDOW)
        s.window(i, SIGNAL, 4)
    gw.run_until_idle()

    lone = FleetGateway(make_sim()).session("only")
    t_fs = lone.fleet_stats(SIGNAL, window=WINDOW)
    t_q = lone.quantile(SIGNAL, 0.75, window=WINDOW)
    t_w = [lone.window(i, SIGNAL, 4) for i in range(3)]
    lone.gateway.run_until_idle()  # one boundary admits the whole trace
    oracle = {("fleet_stats",): t_fs.response.body,
              ("quantile",): t_q.response.body}
    for i, t in enumerate(t_w):
        oracle[("window", i)] = t.response.body

    for i, s in enumerate(sessions):
        by_kind = {r.kind: r for r in s.inbox}
        assert by_kind["fleet_stats"].body == oracle[("fleet_stats",)]
        assert by_kind["quantile"].body == oracle[("quantile",)]
        assert by_kind["window"].body == oracle[("window", i)]


# --------------------------------------------------------------------- #
# statistics correctness (independent numpy oracle)                     #
# --------------------------------------------------------------------- #
def _host_merge(refs, q):
    """Re-derive the fleet quantile from per-vehicle reference sketches
    the way `merge_quantile_sketches` + `_FleetStats.quantile` do."""
    vals, ws = [], []
    for r in refs:
        c = r["count"]
        if c == 0:
            continue
        vals += r["qsk"]
        ws += [np.float32(c) / np.float32(len(r["qsk"]))] * len(r["qsk"])
    order = np.argsort(np.asarray(vals, np.float32), kind="stable")
    v = np.asarray(vals, np.float32)[order]
    cw = np.cumsum(np.asarray(ws, np.float64)[order])
    target = min(max(q, 0.0), 1.0) * float(cw[-1])
    i = min(int(np.searchsorted(cw, target, side="left")), len(v) - 1)
    return float(v[i])


def test_fleet_stats_match_reference_merge():
    sim = make_sim()
    gw = FleetGateway(sim)
    # snapshot the oracle first: admission reads run in the engine drain,
    # before the plane advances, so they see exactly this ring state
    spec = SketchSpec(window=WINDOW)
    refs = [
        sketch_reference(
            [v for v in sim.plane.window(i, SIGNAL, WINDOW)
             if v is not None and np.isfinite(v)],
            spec,
        )
        for i in range(sim.cfg.n_clients)
    ]
    sess = gw.session("ana")
    t_stats = sess.fleet_stats(SIGNAL, window=WINDOW, quantiles=(0.5, 0.9))
    t_q = sess.quantile(SIGNAL, 0.9, window=WINDOW)
    gw.run_until_idle()
    body = t_stats.response.body
    assert body["participants"] == sum(1 for r in refs if r["count"])
    assert body["count"] == sum(r["count"] for r in refs)
    hist = np.sum([r["hist"] for r in refs], axis=0)
    assert body["hist"] == [int(v) for v in hist]
    mean = (
        sum(r["count"] * r["mean"] for r in refs) / body["count"]
    )
    assert body["mean"] == pytest.approx(mean, rel=1e-5)
    assert body["quantiles"]["p50"] == pytest.approx(
        _host_merge(refs, 0.5), rel=1e-6
    )
    assert body["quantiles"]["p90"] == pytest.approx(
        _host_merge(refs, 0.9), rel=1e-6
    )
    assert t_q.response.body["value"] == body["quantiles"]["p90"]


def test_host_and_sharded_planes_serve_identical_bodies():
    """Plane backend is an implementation detail: statistics and window
    reads answer bit-identically on host and client-sharded planes. (In
    CI's multi-device lane this crosses a real 8-device layout.)"""
    bodies = []
    for plane in ("host", "sharded"):
        gw = FleetGateway(make_sim(plane=plane))
        sess = gw.session("ana")
        sess.fleet_stats(SIGNAL, window=WINDOW, quantiles=(0.25, 0.9))
        sess.quantile(SIGNAL, 0.5, window=WINDOW)
        sess.window(5, SIGNAL, 6)
        gw.run_until_idle()
        bodies.append([r.body for r in sess.inbox])
    assert bodies[0] == bodies[1]


# --------------------------------------------------------------------- #
# submissions, progress, backpressure, errors                           #
# --------------------------------------------------------------------- #
def test_progress_observes_in_flight_round():
    """An analyst can watch a slow round: stragglers keep the round open
    across ticks, and per-ticket progress reads see live counts."""
    gw = FleetGateway(make_sim(straggler_fraction=0.5))
    sess = gw.session("ana")
    round_t = sess.submit_round(dim=8, n_samples=4)
    mid = []
    for _ in range(40):
        gw.tick()
        if round_t.done:
            break
        mid.append(sess.progress(round_t))
    assert round_t.done and round_t.response.ok
    served = [t.response for t in mid if t.done and t.response.ok]
    assert served, "round closed before any progress read was admitted"
    for r in served:
        total = r.body["total"]
        assert total > 0
        parts = (
            r.body["finished"] + r.body["error"]
            + r.body["canceled"] + r.body["active"]
        )
        assert parts == total
    # counts are monotone while the round drains
    fin = [r.body["finished"] for r in served]
    assert fin == sorted(fin)
    assert round_t.response.body["participants"] <= served[0].body["total"]


def test_admit_per_tick_throttles_deterministically():
    """Overload becomes queueing delay: 5 requests through a 1/tick
    admission cap are served on 5 consecutive boundaries."""
    gw = FleetGateway(make_sim(), admit_per_tick=1)
    sess = gw.session("ana")
    t0 = gw.sim.t
    tickets = [sess.gauges() for _ in range(5)]
    gw.run_until_idle()
    assert [t.response.served_tick for t in tickets] == [
        t0 + 1 + i for i in range(5)
    ]
    assert [t.response.ticks for t in tickets] == [1, 2, 3, 4, 5]


def test_bad_requests_answer_instead_of_crashing():
    gw = FleetGateway(make_sim())
    sess = gw.session("ana")
    unknown_client = sess.signal("veh-none", SIGNAL)
    unknown_kind = sess.ask("divine")
    stale_progress = sess.progress(10_000)
    gw.run_until_idle()
    for t in (unknown_client, unknown_kind, stale_progress):
        assert t.done and not t.response.ok
        assert "error" in t.response.body
    # the world is still serviceable afterwards
    ok = sess.gauges()
    gw.run_until_idle()
    assert ok.response.ok


def test_gateway_requires_event_engine():
    sim = FleetSimulator(SimConfig(n_clients=8, backends=Backends(engine="dense")))
    with pytest.raises(ValueError, match="event engine"):
        FleetGateway(sim)
    with pytest.raises(ValueError, match="admit_per_tick"):
        FleetGateway(make_sim(n=8), admit_per_tick=0)
