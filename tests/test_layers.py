"""Numerics of the substrate layers against materializing references."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import (
    flash_attention,
    local_attention,
    reference_attention,
)
from repro.models.layers import chunked_softmax_xent
from repro.models.mamba import mamba_scan_chunked
from repro.models.moe import moe_apply, moe_reference
from repro.models.xlstm import (
    mlstm_apply,
    mlstm_init,
    mlstm_recurrent,
    mlstm_state_init,
)
from repro.kernels.ref import ssm_scan_ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("S,H,KV,D", [(128, 8, 4, 32), (256, 4, 1, 64), (128, 6, 2, 48)])
def test_flash_attention_matches_reference(S, H, KV, D):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, S, H, D))
    k = jax.random.normal(ks[1], (2, S, KV, D))
    v = jax.random.normal(ks[2], (2, S, KV, D))
    out = flash_attention(q, k, v, causal=True, q_block=32, kv_block=64)
    ref = reference_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("S,w", [(256, 64), (512, 128), (256, 32)])
def test_local_attention_matches_reference(S, w):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, S, 4, 32))
    k = jax.random.normal(ks[1], (2, S, 2, 32))
    v = jax.random.normal(ks[2], (2, S, 2, 32))
    out = local_attention(q, k, v, window=w, q_block=32)
    ref = reference_attention(q, k, v, causal=True, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_chunked_xent_matches_dense():
    h = jax.random.normal(KEY, (2, 64, 32))
    w = jax.random.normal(KEY, (32, 101))
    y = jax.random.randint(KEY, (2, 64), 0, 101)
    loss = chunked_softmax_xent(h, w, y, chunk=16)
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    dense = -jnp.mean(
        jnp.take_along_axis(jax.nn.log_softmax(logits), y[..., None], -1)
    )
    np.testing.assert_allclose(float(loss), float(dense), rtol=1e-5)


def test_chunked_xent_grad_flows():
    h = jax.random.normal(KEY, (2, 64, 32))
    w = jax.random.normal(KEY, (32, 101))
    y = jax.random.randint(KEY, (2, 64), 0, 101)
    g = jax.grad(lambda w: chunked_softmax_xent(h, w, y, chunk=16))(w)
    assert np.isfinite(np.asarray(g)).all()


@pytest.mark.parametrize("chunk", [8, 32, 128])
def test_mamba_chunked_scan_matches_sequential(chunk):
    B, S, inner, state = 2, 128, 32, 8
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, inner))) * 0.1
    Bm = jax.random.normal(ks[1], (B, S, state))
    Cm = jax.random.normal(ks[2], (B, S, state))
    x = jax.random.normal(ks[3], (B, S, inner))
    A = -jnp.exp(jax.random.normal(ks[4], (inner, state)) * 0.5)
    y, h = mamba_scan_chunked(dt, Bm, Cm, x, A, chunk=chunk)
    y_ref, h_ref = ssm_scan_ref(dt, Bm, Cm, x, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref), atol=1e-4)


def test_moe_matches_reference_when_capacity_is_ample():
    B, S, d, ff, E, k = 2, 32, 16, 32, 4, 2
    params = jax.tree.map(
        lambda x: x.astype(jnp.float32),
        __import__("repro.models.moe", fromlist=["moe_init"]).moe_init(
            KEY, d, ff, E, jnp.float32
        ),
    )
    x = jax.random.normal(KEY, (B, S, d))
    out = moe_apply(params, x, top_k=k, capacity_factor=8.0)  # no overflow
    ref = moe_reference(params, x, top_k=k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_moe_decode_path_matches_reference():
    B, d, ff, E, k = 4, 16, 32, 4, 2
    from repro.models.moe import moe_init

    params = moe_init(KEY, d, ff, E, jnp.float32)
    x = jax.random.normal(KEY, (B, 1, d))
    out = moe_apply(params, x, top_k=k)
    ref = moe_reference(params, x, top_k=k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_mlstm_parallel_matches_recurrent():
    B, S, d, H = 2, 64, 32, 4
    params = mlstm_init(KEY, d, H, jnp.float32)
    x = jax.random.normal(KEY, (B, S, d)) * 0.5
    out_par = mlstm_apply(params, x, n_heads=H)
    out_rec, _ = mlstm_recurrent(
        params, x, mlstm_state_init(B, H, d // H), n_heads=H
    )
    np.testing.assert_allclose(
        np.asarray(out_par), np.asarray(out_rec), atol=2e-3
    )
