"""Columnar per-client arena: `FleetColumns` row allocation/growth/
snapshot contracts, arena-backed `ClientRecord`/`EdgeClient` scalars
staying bit-compatible with the unbound (local) fallback, `deep_sizeof`
accounting, `FleetMetrics.fleet_gauges`, and the simulator's
`memory_report` breakdown."""
import numpy as np
import pytest

from repro.core.columns import COLUMN_SPECS, FleetColumns, deep_sizeof
from repro.core.statestore import ClientRecord, StateStore
from repro.fleet import Backends, FleetSimulator, SimConfig


# --------------------------------------------------------------------- #
# arena contracts                                                        #
# --------------------------------------------------------------------- #
def test_row_allocation_is_stable_and_defaulted():
    cols = FleetColumns(2)
    a = cols.row_for("veh-000")
    b = cols.row_for("veh-001")
    assert (a, b) == (0, 1)
    assert cols.row_for("veh-000") == 0  # idempotent
    assert cols.row_of("veh-007") is None
    cols.clock[a] = 41
    assert cols.n_rows == 2
    assert bool(cols.online[b]) and not bool(cols.runnable[b])


def test_growth_preserves_data_and_is_geometric():
    cols = FleetColumns(1)
    cols.row_for("x")
    cols.clock[0] = 9
    cols.ensure(50)
    assert cols.capacity >= 50
    assert int(cols.clock[0]) == 9 and cols.row_of("x") == 0
    cap = cols.capacity
    cols.ensure(cap)  # no-op within capacity
    assert cols.capacity == cap


def test_snapshot_load_roundtrip():
    cols = FleetColumns(4)
    for i in range(3):
        cols.row_for(f"veh-{i:03d}")
    cols.clock[:3] = [5, 6, 7]
    cols.unacked[1] = 2
    cols.straggler[2] = True
    snap = cols.snapshot()
    assert set(snap) == set(COLUMN_SPECS)
    assert snap["clock"].shape == (3,)

    other = FleetColumns(1)
    other.load(snap, ["veh-000", "veh-001", "veh-002"])
    assert other.n_rows == 3
    assert other.row_of("veh-002") == 2
    assert list(other.clock[:3]) == [5, 6, 7]
    assert int(other.unacked[1]) == 2 and bool(other.straggler[2])
    assert other.nbytes() == sum(
        other.capacity * dt.itemsize for dt in COLUMN_SPECS.values()
    )


# --------------------------------------------------------------------- #
# arena-backed viewers == local-scalar fallback                          #
# --------------------------------------------------------------------- #
def test_client_record_dispatches_through_the_arena():
    rec = ClientRecord("veh-000", logical_clock=3, online=False)
    assert rec.logical_clock == 3 and rec.online is False
    cols = FleetColumns(2)
    rec.bind(cols)  # locals move into the arena
    assert int(cols.clock[0]) == 3 and not bool(cols.online[0])
    rec.logical_clock = 8
    rec.online = True
    assert int(cols.clock[0]) == 8 and bool(cols.online[0])
    assert rec.logical_clock == 8 and rec.online is True
    assert "veh-000" in repr(rec)


def test_statestore_attach_columns_binds_existing_and_future_records():
    store = StateStore()
    store.register_client("veh-000")
    cols = FleetColumns(2)
    store.attach_columns(cols)
    store.register_client("veh-001")
    store._bump_clock("veh-000")
    assert int(cols.clock[cols.row_of("veh-000")]) >= 1
    assert cols.n_rows == 2


def test_simulator_threads_one_arena_through_every_layer():
    sim = FleetSimulator(SimConfig(
        n_clients=6, seed=0, straggler_fraction=0.5,
        backends=Backends(service="calendar"),
    ))
    assert sim.store.columns is sim.columns
    assert sim.metrics.columns is sim.columns
    assert sim.pool.columns is sim.columns
    assert sim.columns.n_rows == 6
    # vehicle index == arena row (by construction order)
    for cid, v in sim.pool.vehicles.items():
        assert sim.columns.row_of(cid) == v.metadata["index"]
    g = sim.metrics.fleet_gauges()
    assert g["clients"] == 6 and g["online"] == 6
    assert g["stragglers"] == 3
    cid = next(iter(sim.pool.vehicles))
    sim.pool.power_off(cid)
    assert sim.metrics.fleet_gauges()["online"] == 5


def test_fleet_gauges_empty_without_an_arena():
    from repro.fleet.metrics import FleetMetrics
    assert FleetMetrics().fleet_gauges() == {}


# --------------------------------------------------------------------- #
# deep_sizeof + memory_report                                            #
# --------------------------------------------------------------------- #
def test_deep_sizeof_counts_numpy_buffers_and_memoizes_sharing():
    arr = np.zeros(1000, np.float64)
    assert deep_sizeof(arr) >= arr.nbytes
    # the same array reachable twice is billed once
    assert deep_sizeof([arr, arr]) < 2 * arr.nbytes


def test_deep_sizeof_walks_slots_and_dicts():
    class Slotted:
        __slots__ = ("a", "b")

        def __init__(self):
            self.a = np.zeros(500, np.int64)
            self.b = "x" * 100

    s = Slotted()
    assert deep_sizeof(s) >= s.a.nbytes + 100
    assert deep_sizeof({"k": s}) >= s.a.nbytes


def test_memory_report_categories_cover_the_total():
    sim = FleetSimulator(SimConfig(n_clients=16, seed=1))
    rep = sim.memory_report()
    cats = ("plane", "columns", "docs", "queues", "clients", "other")
    assert rep["n_clients"] == 16
    assert all(rep[c] >= 0 for c in cats)
    assert rep["total"] == sum(rep[c] for c in cats)
    assert rep["bytes_per_client"] == pytest.approx(rep["total"] / 16)
    assert rep["columns"] >= sim.columns.nbytes()
    table = FleetSimulator.format_memory_report(rep)
    assert "bytes/client" in table and "columns" in table


def test_slotted_control_plane_objects_reject_stray_attributes():
    sim = FleetSimulator(SimConfig(n_clients=2, seed=0))
    v = next(iter(sim.pool.vehicles.values()))
    with pytest.raises(AttributeError):
        v.client.some_new_attribute = 1
    rec = sim.store.register_client(next(iter(sim.pool.vehicles)))
    with pytest.raises(AttributeError):
        rec.some_new_attribute = 1
