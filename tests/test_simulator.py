"""Fleet simulator: determinism, fleet-scale idempotent ingestion, churn,
stragglers, delayed delivery, and the vectorized aggregation path."""
import numpy as np
import pytest

from repro.core import Broker, FaultPlan, seeded_fault_plan
from repro.fleet import (
    FedConfig,
    FleetSimulator,
    SimConfig,
    aggregate_packed,
    aggregate_reference,
    stack_deltas,
)
from repro.fleet.rounds import pack_delta


# --------------------------------------------------------------------- #
# broker: delay + seeded schedules                                       #
# --------------------------------------------------------------------- #
def test_delayed_messages_release_in_order():
    delays = {0: 2, 1: 1}  # by msg_id; msg 2 undelayed
    broker = Broker(FaultPlan(delay=lambda m: delays.get(m.msg_id, 0)))
    sub = broker.subscribe("t")
    broker.publish("t", "a")  # msg 0: due at tick 2
    broker.publish("t", "b")  # msg 1: due at tick 1
    broker.publish("t", "c")  # msg 2: immediate
    assert [m.value for m in sub.drain()] == ["c"]
    assert broker.in_flight == 2
    broker.advance(1)
    assert [m.value for m in sub.drain()] == ["b"]
    broker.advance(1)
    assert [m.value for m in sub.drain()] == ["a"]
    assert broker.in_flight == 0


def test_seeded_fault_plan_is_deterministic_and_seed_sensitive():
    a = seeded_fault_plan(1, p_drop=0.5, max_delay=3)
    b = seeded_fault_plan(1, p_drop=0.5, max_delay=3)
    c = seeded_fault_plan(2, p_drop=0.5, max_delay=3)
    from repro.core.broker import Message

    msgs = [Message("t", None, i) for i in range(200)]
    assert [a.drop(m) for m in msgs] == [b.drop(m) for m in msgs]
    assert [a.delay(m) for m in msgs] == [b.delay(m) for m in msgs]
    assert [a.drop(m) for m in msgs] != [c.drop(m) for m in msgs]
    rate = sum(a.drop(m) for m in msgs) / len(msgs)
    assert 0.3 < rate < 0.7
    assert all(0 <= a.delay(m) <= 3 for m in msgs)


def test_exact_topic_index_matches_wildcards_too():
    broker = Broker()
    exact = broker.subscribe("clients/v1/clock")
    wild = broker.subscribe("clients/*/clock")
    broker.publish("clients/v1/clock", 1)
    broker.publish("clients/v2/clock", 2)
    assert [m.value for m in exact.drain()] == [1]
    assert [m.value for m in wild.drain()] == [1, 2]
    broker.unsubscribe(exact)
    broker.publish("clients/v1/clock", 3)
    assert len(exact) == 0


# --------------------------------------------------------------------- #
# vectorized aggregation                                                 #
# --------------------------------------------------------------------- #
def test_batched_aggregation_matches_reference():
    rng = np.random.default_rng(0)
    msgs = [
        pack_delta(rng.standard_normal(1000).astype(np.float32), row=256)
        for _ in range(32)
    ]
    assert np.allclose(
        aggregate_packed(msgs), aggregate_reference(msgs), atol=1e-6
    )
    w = rng.random(32).astype(np.float32)
    assert np.allclose(
        aggregate_packed(msgs, w), aggregate_reference(msgs, w), atol=1e-6
    )


def test_heterogeneous_shapes_fall_back_to_reference():
    rng = np.random.default_rng(1)
    msgs = [
        pack_delta(rng.standard_normal(512).astype(np.float32), row=256),
        pack_delta(rng.standard_normal(768).astype(np.float32), row=256),
    ]
    assert stack_deltas(msgs) is None
    with pytest.raises(ValueError):
        # mixed lengths cannot be averaged — both paths must agree on that
        aggregate_packed(msgs)


# --------------------------------------------------------------------- #
# the fleet-scale properties                                             #
# --------------------------------------------------------------------- #
FED = FedConfig(local_steps=3, local_lr=0.2, deadline_fraction=1.0)


def _run(cfg: SimConfig, fed: FedConfig = FED, rounds: int = 2):
    sim = FleetSimulator(cfg)
    driver = sim.run_federated(fed, dim=16, rounds=rounds, n_samples=16)
    return sim, driver


def test_lossy_256_client_round_matches_fault_free():
    """Idempotent ingestion at fleet scale: a seeded lossy broker schedule
    (drops, duplicates, delays) must converge to the *exact* aggregate of
    the fault-free run — the paper's resiliency argument, mechanized."""
    _, lossy = _run(
        SimConfig(
            n_clients=256, seed=3, p_drop=0.2, p_duplicate=0.1, max_delay=3
        )
    )
    _, clean = _run(SimConfig(n_clients=256, seed=3))
    assert np.array_equal(lossy.w, clean.w)
    assert all(r["participants"] == 256 for r in lossy.history)


def test_same_seed_same_aggregate():
    cfg = SimConfig(
        n_clients=64,
        seed=11,
        p_drop=0.15,
        p_duplicate=0.05,
        max_delay=2,
        p_leave=0.01,
        p_return=0.3,
        straggler_fraction=0.2,
    )
    fed = FedConfig(
        local_steps=3, local_lr=0.2, deadline_fraction=0.7, deadline_pumps=48
    )
    _, a = _run(cfg, fed, rounds=3)
    _, b = _run(cfg, fed, rounds=3)
    assert np.array_equal(a.w, b.w)
    assert [r["participants"] for r in a.history] == [
        r["participants"] for r in b.history
    ]


def test_stragglers_get_canceled_and_rounds_still_converge():
    sim, driver = _run(
        SimConfig(
            n_clients=48, seed=5, straggler_fraction=0.25, straggler_period=8
        ),
        FedConfig(
            local_steps=3,
            local_lr=0.2,
            deadline_fraction=0.7,
            deadline_pumps=32,
        ),
        rounds=3,
    )
    assert sum(r["canceled"] for r in driver.history) > 0
    assert (
        driver.history[-1]["dist_to_optimum"]
        < driver.history[0]["dist_to_optimum"]
    )
    s = sim.metrics.summary()
    assert s["rounds"] == 3 and s["canceled_total"] > 0


def test_churn_mid_round_never_stalls_the_fleet():
    sim, driver = _run(
        SimConfig(n_clients=32, seed=9, p_leave=0.05, p_return=0.3),
        FedConfig(
            local_steps=2,
            local_lr=0.2,
            deadline_fraction=0.5,
            deadline_pumps=48,
        ),
        rounds=3,
    )
    assert all(r["participants"] >= 1 for r in driver.history)
    # churn actually happened: someone was offline or missed a round
    assert any(
        r.online_at_start < 32 or r.participants < r.online_at_start
        for r in sim.metrics.rounds
    )
    assert len(sim.metrics.rounds) == 3


def test_new_vehicles_can_join_mid_experiment():
    sim, driver = _run(SimConfig(n_clients=8, seed=1), rounds=1)
    cid = sim.pool.add_vehicle()
    sim.pool.vehicles[cid].client.run_until_idle()
    rec = driver.run_round(1, pump=sim.tick)
    assert rec["participants"] == 9
