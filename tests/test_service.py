"""Event-driven fleet service scheduler: bit-for-bit parity with the
dense poll-loop oracle under faults + churn + stragglers, O(runnable)
idle behaviour, wake plumbing across power cycles and joins, and the
round-metrics loss bugfix."""
import numpy as np
import pytest

from repro.fleet import (
    DensePollService,
    FedConfig,
    FleetMetrics,
    FleetServiceScheduler,
    FleetSimulator,
    RoundMetrics,
    SimConfig,
    mean_reported_loss,
)
from repro.fleet.rounds import FederatedDriver


def _fingerprint(sim: FleetSimulator, driver) -> tuple:
    """Everything the parity contract pins down: the aggregate, the broker
    counters (same message-id sequence => same seeded fault schedule),
    per-round participation, and consumed ticks."""
    return (
        driver.w.copy(),
        (sim.broker.published, sim.broker.delivered, sim.broker.dropped),
        [r["participants"] for r in driver.history],
        [r["canceled"] for r in driver.history],
        sim.t,
    )


def _run(mode: str, **overrides) -> tuple:
    cfg = dict(
        n_clients=48,
        seed=17,
        p_drop=0.15,
        p_duplicate=0.05,
        max_delay=2,
        p_leave=0.02,
        p_return=0.3,
        straggler_fraction=0.25,
        straggler_period=8,
        service=mode,
    )
    cfg.update(overrides)
    sim = FleetSimulator(SimConfig(**cfg))
    driver = sim.run_federated(
        FedConfig(
            local_steps=2, local_lr=0.2, deadline_fraction=0.7,
            deadline_pumps=48,
        ),
        dim=16,
        rounds=3,
        n_samples=8,
    )
    return _fingerprint(sim, driver)


# --------------------------------------------------------------------- #
# the tentpole contract: scheduler == dense oracle, bit for bit          #
# --------------------------------------------------------------------- #
def test_scheduler_matches_dense_oracle_bit_for_bit():
    """Same SimConfig (faults + churn + stragglers) through the dense
    poll-loop oracle and the event-driven scheduler must yield identical
    aggregates AND identical broker counters — the strongest available
    witness that the event interleaving (message-id sequence, hence the
    seeded fault schedule) is reproduced exactly."""
    w_d, counters_d, parts_d, canc_d, t_d = _run("dense")
    w_s, counters_s, parts_s, canc_s, t_s = _run("scheduler")
    assert np.array_equal(w_d, w_s)
    assert counters_d == counters_s
    assert parts_d == parts_s and canc_d == canc_s
    assert t_d == t_s


def test_scheduler_parity_on_clean_full_participation_run():
    a = _run("dense", p_drop=0.0, p_duplicate=0.0, max_delay=0,
             p_leave=0.0, p_return=0.0)
    b = _run("scheduler", p_drop=0.0, p_duplicate=0.0, max_delay=0,
             p_leave=0.0, p_return=0.0)
    assert np.array_equal(a[0], b[0]) and a[1:] == b[1:]


def test_scheduler_is_default_and_deterministic():
    sim = FleetSimulator(SimConfig(n_clients=8, seed=0))
    assert isinstance(sim.service, FleetServiceScheduler)
    dense = FleetSimulator(SimConfig(n_clients=8, seed=0, service="dense"))
    assert isinstance(dense.service, DensePollService)
    a = _run("scheduler")
    b = _run("scheduler")
    assert np.array_equal(a[0], b[0]) and a[1:] == b[1:]


def test_unknown_service_kind_raises():
    with pytest.raises(ValueError, match="unknown service"):
        FleetSimulator(SimConfig(n_clients=2, service="threads"))


# --------------------------------------------------------------------- #
# O(runnable): idle clients are not touched                              #
# --------------------------------------------------------------------- #
def test_idle_fleet_services_only_the_resync_due_phase_class():
    """A quiesced 32-vehicle fleet with resync_period=8: each tick exactly
    the 4 clients whose (t + i) phase matches dial in; the other 28 are
    never polled (the dense loop advanced all 32 every tick)."""
    sim = FleetSimulator(SimConfig(n_clients=32, seed=1, resync_period=8))
    for _ in range(16):
        sim.tick()
        assert sim.service.last_serviced == 4
    dense = FleetSimulator(
        SimConfig(n_clients=32, seed=1, resync_period=8, service="dense")
    )
    dense.tick()
    assert dense.service.last_serviced == 32


def test_broker_delivery_wakes_exactly_the_target_client():
    sim = FleetSimulator(SimConfig(n_clients=16, seed=2, resync_period=1024))
    sim.tick()
    assert sim.service.last_serviced <= 1  # mostly idle, huge resync period
    payload = sim.user.payload("import autospada\nautospada.publish({'ok': 1})\n")
    assign = sim.user.assignment(
        "one-task", [sim.user.task("veh-003", payload)]
    ).commit()
    # commit published a clock bump to veh-003 only: the wake hook makes it
    # runnable, the next ticks service it to completion without a fleet scan
    for _ in range(8):
        sim.tick()
        assert sim.service.last_serviced <= 2
    assert set(assign.statuses().values()) == {"FINISHED"}
    assert assign.results()[assign.tasks[0].task_id] == [{"ok": 1}]


def test_power_cycle_rewires_wake_hooks():
    sim = FleetSimulator(SimConfig(n_clients=6, seed=4, resync_period=1024))
    cid = "veh-002"
    sim.pool.power_off(cid)
    sim.tick()
    sim.pool.power_on(cid)  # a NEW EdgeClient instance: hooks must follow
    sim.pool.vehicles[cid].client.run_until_idle()
    payload = sim.user.payload("import autospada\nautospada.publish({'v': 7})\n")
    assign = sim.user.assignment(
        "after-reboot", [sim.user.task(cid, payload)]
    ).commit()
    for _ in range(8):
        sim.tick()
    assert set(assign.statuses().values()) == {"FINISHED"}


def test_new_vehicles_join_mid_experiment_under_the_scheduler():
    sim = FleetSimulator(SimConfig(n_clients=8, seed=1))
    driver = sim.run_federated(
        FedConfig(local_steps=3, local_lr=0.2, deadline_fraction=1.0),
        dim=16, rounds=1, n_samples=16,
    )
    for _ in range(4):  # scheduler arrays + plane capacity must both grow
        cid = sim.pool.add_vehicle()
        sim.pool.vehicles[cid].client.run_until_idle()
    rec = driver.run_round(1, pump=sim.tick)
    assert rec["participants"] == 12


# --------------------------------------------------------------------- #
# bugfix: a result without `loss` must not poison mean_client_loss       #
# --------------------------------------------------------------------- #
def test_mean_reported_loss_filters_missing_and_non_finite():
    msgs = [
        {"loss": 1.0},
        {},  # legacy upload without a loss field
        {"loss": float("nan")},
        {"loss": None},
        {"loss": "oops"},  # non-numeric: skipped, must not crash the round
        {"loss": [1.0]},
        {"loss": 3.0},
    ]
    assert mean_reported_loss(msgs) == pytest.approx(2.0)
    assert mean_reported_loss([{}, {"loss": float("inf")}]) is None
    assert mean_reported_loss([]) is None


#: ROUND_PAYLOAD's upload shape, but only even-indexed clients report a
#: loss (data_seed == 1000*round + client_index)
_PARTIAL_LOSS_PAYLOAD = """
import autospada, base64
import numpy as np

p = autospada.get_parameters()
w = np.asarray(p["weights"], dtype=np.float32)
delta = np.full_like(w, 0.01)
row = 256
n = delta.shape[0]
pad = (-n) % row
x = np.pad(delta, (0, pad)).reshape(-1, row)
absmax = np.maximum(np.abs(x).max(axis=1, keepdims=True), 1e-12)
s = absmax / 127.0
q = np.clip(np.round(x / s), -127, 127).astype(np.int8)
msg = {
    "round": int(p["round"]),
    "q": base64.b64encode(q.tobytes()).decode(),
    "s": [float(v) for v in s[:, 0]],
    "n": int(n),
    "row": row,
    "n_samples": int(p["n_samples"]),
}
if int(p["data_seed"]) % 2 == 0:
    msg["loss"] = float(int(p["data_seed"]) % 7)
autospada.publish(msg)
"""


def test_round_with_partially_reported_losses_yields_finite_mean():
    sim = FleetSimulator(SimConfig(n_clients=4, seed=0))
    driver = FederatedDriver(
        sim.user,
        FedConfig(local_steps=1, local_lr=0.1, deadline_fraction=1.0),
        dim=8,
        w_true=np.zeros(8, np.float32),
        n_samples=4,
        payload_source=_PARTIAL_LOSS_PAYLOAD,
    )
    rec = driver.run_round(0, pump=sim.tick)
    assert rec["participants"] == 4
    # clients 0 and 2 reported (0 % 7, 2 % 7); 1 and 3 omitted the field —
    # before the fix this was NaN and poisoned the whole metrics table
    assert rec["mean_client_loss"] == pytest.approx(1.0)


def test_round_with_no_reported_losses_records_none_not_nan():
    no_loss = _PARTIAL_LOSS_PAYLOAD.replace(
        'if int(p["data_seed"]) % 2 == 0:\n    msg["loss"] = float(int(p["data_seed"]) % 7)\n',
        "",
    )
    assert '"loss"' not in no_loss
    sim = FleetSimulator(SimConfig(n_clients=3, seed=0))
    driver = FederatedDriver(
        sim.user,
        FedConfig(local_steps=1, local_lr=0.1, deadline_fraction=1.0),
        dim=8,
        w_true=np.zeros(8, np.float32),
        n_samples=4,
        payload_source=no_loss,
    )
    rec = driver.run_round(0, pump=sim.tick)
    assert rec["participants"] == 3
    assert rec["mean_client_loss"] is None
    # the metrics table renders a None loss as "-", not "None"/"nan"
    metrics = FleetMetrics()
    metrics.record(
        RoundMetrics(
            round=0,
            online_at_start=rec["participants"],
            participants=rec["participants"],
            canceled=rec["canceled"],
            ticks=1,
            published=0,
            delivered=0,
            dropped=0,
            wall_s=0.0,
            mean_client_loss=rec["mean_client_loss"],
            dist_to_optimum=rec["dist_to_optimum"],
        )
    )
    row = metrics.format_table().splitlines()[1]
    assert "nan" not in row and "None" not in row
    assert row.split()[-2] == "-"  # the loss column
