"""Event-driven churn: the O(events) heap schedule must reproduce the
O(N)-scan dense oracle's toggle sequence exactly at a fixed seed, stay
row-stable under membership growth, and keep the simulator deterministic
(and actually churning) end to end."""
import numpy as np
import pytest

from repro.fleet import FedConfig, FleetSimulator, SimConfig
from repro.fleet.churn import DenseChurn, EventChurn, geometric_gap, make_churn


def _drive(churn, n=32, ticks=200, external=()):
    """Run a toy world against a churn schedule: apply due toggles, feed
    the resulting state back via notify (as FleetPool does), and inject
    external power flips at scripted (tick, index) points."""
    online = {f"v{i}": True for i in range(n)}
    for i in range(n):
        churn.watch(f"v{i}", i, True, now=0)
    external = {(t, f"v{i}") for t, i in external}
    log = []
    for t in range(1, ticks + 1):
        for cid in churn.pop_due(t):
            online[cid] = not online[cid]
            idx = int(cid[1:])
            churn.notify(cid, idx, online[cid])
            log.append((t, cid, online[cid]))
        for t_ext, cid in sorted(external):
            if t_ext == t:
                online[cid] = not online[cid]
                churn.notify(cid, int(cid[1:]), online[cid])
                log.append((t, cid, online[cid], "external"))
    return log


# --------------------------------------------------------------------- #
# the satellite contract: heap == dense scan, bit for bit                #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("p_leave,p_return", [(0.05, 0.3), (0.5, 0.5), (0.01, 0.0)])
def test_event_heap_matches_dense_scan(p_leave, p_return):
    a = _drive(EventChurn(11, p_leave, p_return))
    b = _drive(DenseChurn(11, p_leave, p_return))
    assert a == b
    assert len(a) > 0  # churn actually happened


def test_parity_survives_external_power_flips():
    ext = [(10, 3), (10, 7), (55, 3), (90, 0)]
    a = _drive(EventChurn(5, 0.04, 0.25), external=ext)
    b = _drive(DenseChurn(5, 0.04, 0.25), external=ext)
    assert a == b


def test_zero_probabilities_schedule_nothing():
    assert _drive(EventChurn(0, 0.0, 0.0)) == []
    # p_return=0: a vehicle that leaves never returns via churn
    log = _drive(EventChurn(2, 0.2, 0.0), n=8, ticks=120)
    went_off = {cid for _, cid, on, *_ in log if not on}
    came_back = {cid for _, cid, on, *_ in log if on}
    assert went_off and not came_back


def test_streams_are_per_vehicle_and_row_stable():
    """Adding vehicle k never perturbs vehicles < k: per-vehicle seeded
    streams, exactly the scenario generators' row-stability contract."""
    small = _drive(EventChurn(7, 0.1, 0.3), n=4, ticks=80)
    large = _drive(EventChurn(7, 0.1, 0.3), n=9, ticks=80)
    assert [e for e in large if int(e[1][1:]) < 4] == small


def test_geometric_gap_inverse_cdf():
    assert geometric_gap(0.0, 0.5) == 1  # u=0 is the earliest success
    assert geometric_gap(0.999, 1.0) == 1  # p=1 fires next tick
    # median of Geometric(0.5) is 1; u just under the CDF step lands 1
    assert geometric_gap(0.49, 0.5) == 1
    assert geometric_gap(0.51, 0.5) == 2
    # tiny p gives long horizons, never zero or negative
    assert geometric_gap(0.5, 0.001) >= 1


def test_make_churn_selects_and_rejects():
    assert isinstance(make_churn("event", 0, 0.1, 0.1), EventChurn)
    assert isinstance(make_churn("dense", 0, 0.1, 0.1), DenseChurn)
    with pytest.raises(ValueError, match="unknown churn"):
        make_churn("poisson", 0, 0.1, 0.1)


# --------------------------------------------------------------------- #
# simulator integration                                                  #
# --------------------------------------------------------------------- #
def _run_sim(churn_kind, **overrides):
    cfg = dict(
        n_clients=24, seed=9, p_leave=0.05, p_return=0.3, churn=churn_kind
    )
    cfg.update(overrides)
    sim = FleetSimulator(SimConfig(**cfg))
    drv = sim.run_federated(
        FedConfig(
            local_steps=2, local_lr=0.2, deadline_fraction=0.5,
            deadline_pumps=48,
        ),
        dim=8,
        rounds=3,
        n_samples=8,
    )
    counters = (sim.broker.published, sim.broker.delivered, sim.broker.dropped)
    return drv.w.copy(), counters, sim


def test_simulator_event_churn_matches_dense_churn_oracle():
    w_e, c_e, _ = _run_sim("event")
    w_d, c_d, _ = _run_sim("dense")
    assert np.array_equal(w_e, w_d)
    assert c_e == c_d


def test_simulator_churn_is_deterministic_and_still_churns():
    w1, c1, sim = _run_sim("event")
    w2, c2, _ = _run_sim("event")
    assert np.array_equal(w1, w2) and c1 == c2
    assert any(
        r.online_at_start < 24 or r.participants < r.online_at_start
        for r in sim.metrics.rounds
    )


def test_new_vehicles_join_the_churn_schedule():
    """A vehicle added mid-experiment is auto-watched via the pool's
    power-on hook and can be toggled by churn."""
    sim = FleetSimulator(
        SimConfig(n_clients=4, seed=3, p_leave=0.9, p_return=0.9)
    )
    cid = sim.pool.add_vehicle()
    assert cid in sim.churn._online
    offline_seen = False
    for _ in range(30):
        sim.tick()
        offline_seen |= sim.pool.vehicles[cid].client is None
    assert offline_seen
