"""Columnar signal plane: parity with the legacy per-vehicle broker path,
drive-cycle scenario determinism and row stability, CSV adapter
robustness, and simulator determinism with the plane enabled."""
import numpy as np
import pytest

from repro.core.signals import (
    CsvSignalBroker,
    FleetSignalPlane,
    ScriptedSignalBroker,
    SignalHandler,
    parse_signal_csv,
)
from repro.fleet import FedConfig, FleetSimulator, SimConfig
from repro.fleet.scenarios import (
    SCENARIOS,
    SIGNALS,
    Scenario,
    build_plane,
    scenario_trace,
    scripted_brokers,
)


# --------------------------------------------------------------------- #
# parity: the plane-backed views are payload-indistinguishable from the  #
# old ScriptedSignalBroker path                                          #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["highway", "urban", "mixed"])
def test_plane_views_match_scripted_broker_sequences(name):
    scen = Scenario(name, seed=13)
    n, ticks = 5, 12
    plane = scen.plane(n)
    old = [SignalHandler(b) for b in scripted_brokers(scen, n, ticks + 2)]
    new = [SignalHandler(plane.view(i)) for i in range(n)]
    for t in range(ticks):
        for i in range(n):
            for sig in SIGNALS:
                assert old[i].get(sig) == new[i].get(sig), (t, i, sig)
                assert old[i].window(sig, 6) == new[i].window(sig, 6), (t, i, sig)
        for h in old:
            h._broker.tick()
        plane.step()


def test_plane_read_unknown_signal_is_none_like_the_old_path():
    plane = build_plane("highway", 2, seed=0)
    h = SignalHandler(plane.view(0))
    assert h.get("Vehicle.DoesNotExist") is None
    assert h.window("Vehicle.DoesNotExist", 4) == []


# --------------------------------------------------------------------- #
# scenarios: seeded, deterministic, row-stable under fleet growth        #
# --------------------------------------------------------------------- #
def test_scenarios_are_deterministic_and_seed_sensitive():
    for name in SCENARIOS:
        a = scenario_trace(Scenario(name, seed=3), 4, 6)
        b = scenario_trace(Scenario(name, seed=3), 4, 6)
        assert np.array_equal(a, b), name
    x = scenario_trace(Scenario("mixed", seed=3), 4, 6)
    y = scenario_trace(Scenario("mixed", seed=4), 4, 6)
    assert not np.array_equal(x, y)


def test_scenario_rows_stable_under_fleet_growth():
    """A vehicle joining must never perturb existing vehicles' streams."""
    small = scenario_trace(Scenario("mixed", seed=9), 4, 8)
    large = scenario_trace(Scenario("mixed", seed=9), 7, 8)
    assert np.array_equal(small, large[:, :4, :])


def test_plane_add_client_grows_without_disturbing_existing_rows():
    plane = build_plane("urban", 3, seed=2)
    plane.step()
    before = plane.values.copy()
    row = plane.add_client()
    assert row == 3 and plane.n_clients == 4
    assert np.array_equal(plane.values[:3], before)
    # the new row produces values and history from the current tick on
    assert plane.read(3, "Vehicle.FuelRate") is not None
    plane.step()
    assert len(plane.window(3, "Vehicle.FuelRate", 8)) == 2


def test_default_road_grade_scenario_matches_legacy_constants():
    plane = build_plane("road-grade", 15, seed=0)
    for i in range(15):
        assert plane.read(i, "Vehicle.RoadGrade") == pytest.approx(
            0.01 * (i % 7)
        )
    t0 = plane.values.copy()
    plane.step()
    assert np.array_equal(plane.values, t0)  # time-invariant by design


# --------------------------------------------------------------------- #
# CSV adapter robustness (satellite)                                     #
# --------------------------------------------------------------------- #
def test_csv_blank_cells_hold_previous_value_in_both_paths():
    csv_text = "a,b\n1,2\n,3\n4,\n"
    h = SignalHandler(CsvSignalBroker(csv_text))
    seq = [h.get("a")]
    for _ in range(3):
        h._broker.tick()
        seq.append(h.get("a"))
    assert seq == [1.0, 1.0, 4.0, 4.0]
    plane = FleetSignalPlane.from_csv_fleet([csv_text])
    pseq = [plane.read(0, "a")]
    for _ in range(3):
        plane.step()
        pseq.append(plane.read(0, "a"))
    assert pseq == seq


def test_csv_leading_blank_reads_none_until_first_observation():
    plane = FleetSignalPlane.from_csv_fleet(["a,b\n,5\n2,6\n"])
    assert plane.read(0, "a") is None and plane.read(0, "b") == 5.0
    plane.step()
    assert plane.read(0, "a") == 2.0


def test_csv_ragged_row_raises_naming_the_row():
    with pytest.raises(ValueError, match=r"row 2 has 3 cells, expected 2"):
        CsvSignalBroker("a,b\n1,2\n1,2,3\n")


def test_csv_bad_cell_raises_naming_column_and_row():
    with pytest.raises(ValueError, match=r"column 'b', row 1.*'oops'"):
        CsvSignalBroker("a,b\n1,oops\n")


def test_csv_empty_raises_clear_error():
    with pytest.raises(ValueError, match="no header"):
        parse_signal_csv("")


def test_csv_duplicate_header_raises_clear_error():
    with pytest.raises(ValueError, match=r"repeats column\(s\): a"):
        parse_signal_csv("a,a,b\n1,2,9\n")


def test_scripted_signals_pause_while_powered_off():
    """Legacy-path semantics the plane refactor must not change: a
    powered-off vehicle's scripted iterators pause until ignition-on."""
    from repro.core.signals import SignalHandler

    sim = FleetSimulator(
        SimConfig(n_clients=2, seed=0),
        signal_fn=lambda i: {"Vehicle.Odo": iter([1.0, 2.0, 3.0, 4.0, 5.0])},
    )
    cid = next(iter(sim.pool.vehicles))
    v = sim.pool.vehicles[cid]
    h = SignalHandler(v.signals)
    assert h.get("Vehicle.Odo") == 1.0
    sim.tick()
    assert h.get("Vehicle.Odo") == 2.0
    sim.pool.power_off(cid)
    sim.tick()
    sim.tick()  # iterator must not advance while the ignition is off
    sim.pool.power_on(cid)
    sim.tick()
    assert h.get("Vehicle.Odo") == 3.0


def test_csv_fleet_plane_aligns_union_of_columns():
    plane = FleetSignalPlane.from_csv_fleet(
        ["speed,fuel\n10,1\n20,2\n", "speed\n30\n40\n"]
    )
    assert plane.names == ("fuel", "speed")
    assert plane.read(1, "speed") == 30.0 and plane.read(1, "fuel") is None
    plane.step()
    plane.step()  # past the trace end: hold last row
    assert plane.read(0, "speed") == 20.0 and plane.read(1, "speed") == 40.0


# --------------------------------------------------------------------- #
# simulator determinism with the plane enabled                           #
# --------------------------------------------------------------------- #
def test_simulator_with_time_varying_scenario_is_deterministic():
    cfg = SimConfig(
        n_clients=12, seed=21, scenario="mixed", p_drop=0.1, max_delay=1
    )
    fed = FedConfig(
        local_steps=2, local_lr=0.2, deadline_fraction=0.8, deadline_pumps=32
    )

    def run():
        sim = FleetSimulator(cfg)
        drv = sim.run_federated(fed, dim=8, rounds=2, n_samples=8)
        return drv.w.copy(), sim.plane.values.copy()

    (w1, v1), (w2, v2) = run(), run()
    assert np.array_equal(w1, w2)
    assert np.array_equal(v1, v2)


def test_simulator_default_uses_plane_and_legacy_signal_fn_still_works():
    from repro.core.signals import constant

    sim = FleetSimulator(SimConfig(n_clients=4, seed=0))
    assert sim.plane is not None and sim.pool.plane is sim.plane
    legacy = FleetSimulator(
        SimConfig(n_clients=4, seed=0),
        signal_fn=lambda i: {"Vehicle.RoadGrade": constant(0.5)},
    )
    assert legacy.plane is None
    legacy.tick()  # the per-vehicle iterator path still ticks fine
    v = next(iter(legacy.pool.vehicles.values()))
    assert SignalHandler(v.signals).get("Vehicle.RoadGrade") == 0.5
