"""Columnar signal plane: parity with the legacy per-vehicle broker path,
drive-cycle scenario determinism and row stability, CSV adapter
robustness, and simulator determinism with the plane enabled."""
import numpy as np
import pytest

from repro.core.signals import (
    CsvSignalBroker,
    FleetSignalPlane,
    SignalHandler,
    parse_signal_csv,
)
from repro.fleet import FedConfig, FleetSimulator, SimConfig
from repro.fleet.scenarios import (
    SCENARIOS,
    SIGNALS,
    Scenario,
    build_plane,
    scenario_trace,
    scripted_brokers,
)


# --------------------------------------------------------------------- #
# parity: the plane-backed views are payload-indistinguishable from the  #
# old ScriptedSignalBroker path                                          #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("name", ["highway", "urban", "mixed"])
def test_plane_views_match_scripted_broker_sequences(name):
    scen = Scenario(name, seed=13)
    n, ticks = 5, 12
    plane = scen.plane(n)
    old = [SignalHandler(b) for b in scripted_brokers(scen, n, ticks + 2)]
    new = [SignalHandler(plane.view(i)) for i in range(n)]
    for t in range(ticks):
        for i in range(n):
            for sig in SIGNALS:
                assert old[i].get(sig) == new[i].get(sig), (t, i, sig)
                assert old[i].window(sig, 6) == new[i].window(sig, 6), (t, i, sig)
        for h in old:
            h._broker.tick()
        plane.step()


def test_plane_read_unknown_signal_is_none_like_the_old_path():
    plane = build_plane("highway", 2, seed=0)
    h = SignalHandler(plane.view(0))
    assert h.get("Vehicle.DoesNotExist") is None
    assert h.window("Vehicle.DoesNotExist", 4) == []


# --------------------------------------------------------------------- #
# scenarios: seeded, deterministic, row-stable under fleet growth        #
# --------------------------------------------------------------------- #
def test_scenarios_are_deterministic_and_seed_sensitive():
    for name in SCENARIOS:
        a = scenario_trace(Scenario(name, seed=3), 4, 6)
        b = scenario_trace(Scenario(name, seed=3), 4, 6)
        assert np.array_equal(a, b), name
    x = scenario_trace(Scenario("mixed", seed=3), 4, 6)
    y = scenario_trace(Scenario("mixed", seed=4), 4, 6)
    assert not np.array_equal(x, y)


def test_scenario_rows_stable_under_fleet_growth():
    """A vehicle joining must never perturb existing vehicles' streams."""
    small = scenario_trace(Scenario("mixed", seed=9), 4, 8)
    large = scenario_trace(Scenario("mixed", seed=9), 7, 8)
    assert np.array_equal(small, large[:, :4, :])


def test_plane_add_client_grows_without_disturbing_existing_rows():
    plane = build_plane("urban", 3, seed=2)
    plane.step()
    before = plane.values.copy()
    row = plane.add_client()
    assert row == 3 and plane.n_clients == 4
    assert np.array_equal(plane.values[:3], before)
    # the new row produces values and history from the current tick on
    assert plane.read(3, "Vehicle.FuelRate") is not None
    plane.step()
    assert len(plane.window(3, "Vehicle.FuelRate", 8)) == 2


def test_default_road_grade_scenario_matches_legacy_constants():
    plane = build_plane("road-grade", 15, seed=0)
    for i in range(15):
        assert plane.read(i, "Vehicle.RoadGrade") == pytest.approx(
            0.01 * (i % 7)
        )
    t0 = plane.values.copy()
    plane.step()
    assert np.array_equal(plane.values, t0)  # time-invariant by design


# --------------------------------------------------------------------- #
# CSV adapter robustness (satellite)                                     #
# --------------------------------------------------------------------- #
def test_csv_blank_cells_hold_previous_value_in_both_paths():
    csv_text = "a,b\n1,2\n,3\n4,\n"
    h = SignalHandler(CsvSignalBroker(csv_text))
    seq = [h.get("a")]
    for _ in range(3):
        h._broker.tick()
        seq.append(h.get("a"))
    assert seq == [1.0, 1.0, 4.0, 4.0]
    plane = FleetSignalPlane.from_csv_fleet([csv_text])
    pseq = [plane.read(0, "a")]
    for _ in range(3):
        plane.step()
        pseq.append(plane.read(0, "a"))
    assert pseq == seq


def test_csv_leading_blank_reads_none_until_first_observation():
    plane = FleetSignalPlane.from_csv_fleet(["a,b\n,5\n2,6\n"])
    assert plane.read(0, "a") is None and plane.read(0, "b") == 5.0
    plane.step()
    assert plane.read(0, "a") == 2.0


def test_csv_ragged_row_raises_naming_the_row():
    with pytest.raises(ValueError, match=r"row 2 has 3 cells, expected 2"):
        CsvSignalBroker("a,b\n1,2\n1,2,3\n")


def test_csv_bad_cell_raises_naming_column_and_row():
    with pytest.raises(ValueError, match=r"column 'b', row 1.*'oops'"):
        CsvSignalBroker("a,b\n1,oops\n")


def test_csv_empty_raises_clear_error():
    with pytest.raises(ValueError, match="no header"):
        parse_signal_csv("")


def test_csv_duplicate_header_raises_clear_error():
    with pytest.raises(ValueError, match=r"repeats column\(s\): a"):
        parse_signal_csv("a,a,b\n1,2,9\n")


def test_scripted_signals_pause_while_powered_off():
    """Legacy-path semantics the plane refactor must not change: a
    powered-off vehicle's scripted iterators pause until ignition-on."""
    from repro.core.signals import SignalHandler

    sim = FleetSimulator(
        SimConfig(n_clients=2, seed=0),
        signal_fn=lambda i: {"Vehicle.Odo": iter([1.0, 2.0, 3.0, 4.0, 5.0])},
    )
    cid = next(iter(sim.pool.vehicles))
    v = sim.pool.vehicles[cid]
    h = SignalHandler(v.signals)
    assert h.get("Vehicle.Odo") == 1.0
    sim.tick()
    assert h.get("Vehicle.Odo") == 2.0
    sim.pool.power_off(cid)
    sim.tick()
    sim.tick()  # iterator must not advance while the ignition is off
    sim.pool.power_on(cid)
    sim.tick()
    assert h.get("Vehicle.Odo") == 3.0


def test_csv_fleet_plane_aligns_union_of_columns():
    plane = FleetSignalPlane.from_csv_fleet(
        ["speed,fuel\n10,1\n20,2\n", "speed\n30\n40\n"]
    )
    assert plane.names == ("fuel", "speed")
    assert plane.read(1, "speed") == 30.0 and plane.read(1, "fuel") is None
    plane.step()
    plane.step()  # past the trace end: hold last row
    assert plane.read(0, "speed") == 20.0 and plane.read(1, "speed") == 40.0


# --------------------------------------------------------------------- #
# streamed CSV ingestion: bit-for-bit with the materializing oracle      #
# --------------------------------------------------------------------- #
_PARITY_CSVS = [
    "a,b\n1,2\n,3\n4,\n7,8\n",      # blanks hold the previous value
    "a,c\n5,\n,9\n",                # short trace: holds its last row
    "b\n\n6\n",                     # blank line, late first observation
    "d\n\n",                        # header-only: never observes anything
]


def test_streamed_csv_plane_matches_materializing_oracle():
    streamed = FleetSignalPlane.from_csv_fleet(_PARITY_CSVS, history=8)
    oracle = FleetSignalPlane.from_csv_fleet(
        _PARITY_CSVS, history=8, streamed=False
    )
    assert streamed.names == oracle.names
    assert streamed.n_clients == oracle.n_clients
    streamed.set_online(2, False)
    oracle.set_online(2, False)
    for t in range(7):  # runs past the longest trace (4 ticks)
        for i in range(oracle.n_clients):
            for name in oracle.names:
                assert streamed.read(i, name) == oracle.read(i, name), (
                    t, i, name,
                )
                assert streamed.window(i, name, 6) == oracle.window(
                    i, name, 6
                )
        if t == 2:
            streamed.set_online(2, True)
            oracle.set_online(2, True)
        streamed.step()
        oracle.step()
    assert np.array_equal(streamed.values, oracle.values, equal_nan=True)
    assert np.array_equal(
        streamed._hist, oracle._hist, equal_nan=True
    )


def test_streamed_csv_plane_validates_eagerly_like_the_oracle():
    # cell errors surface at construction, not first playback of the row
    for bad in ("a,b\n1\n", "a\nx\n", "", "a,a\n1,2\n"):
        with pytest.raises(ValueError):
            FleetSignalPlane.from_csv_fleet(["a\n1\n", bad])


def test_streamed_csv_plane_is_fixed_size_like_the_oracle():
    plane = FleetSignalPlane.from_csv_fleet(["a\n1\n2\n"])
    with pytest.raises(ValueError, match="fixed fleet size"):
        plane.add_client()


# --------------------------------------------------------------------- #
# bugfix: offline rows are NaN-masked in the history ring                #
# --------------------------------------------------------------------- #
def test_offline_rows_are_nan_masked_in_history_ring():
    """Plane time is fleet-global, but a powered-off vehicle observes
    nothing: its ring rows are NaN while offline, so windows after
    re-ignition only contain powered-on observations. The latest-value
    matrix is untouched."""
    plane = build_plane("mixed", 2, seed=3, history=64)
    observed = [plane.read(1, "Vehicle.Speed")]  # tick 0, online
    for _ in range(3):
        plane.step()
        observed.append(plane.read(1, "Vehicle.Speed"))
    plane.set_online(1, False)
    for _ in range(4):
        plane.step()
        # values keep advancing fleet-globally — only the ring is masked
        assert plane.read(1, "Vehicle.Speed") is not None
    plane.set_online(1, True)
    for _ in range(2):
        plane.step()
        observed.append(plane.read(1, "Vehicle.Speed"))
    w = plane.window(1, "Vehicle.Speed", 64)
    assert w == observed  # 4 pre-off + 2 post-on ticks, nothing in between
    # the always-online row saw every tick
    assert len(plane.window(0, "Vehicle.Speed", 64)) == 10


def test_reignition_window_excludes_offline_period_in_simulator():
    sim = FleetSimulator(SimConfig(n_clients=2, seed=0, scenario="mixed"))
    cid = "veh-001"
    for _ in range(4):
        sim.tick()
    sim.pool.power_off(cid)
    for _ in range(3):
        sim.tick()
    sim.pool.power_on(cid)
    sim.pool.vehicles[cid].client.run_until_idle()
    for _ in range(2):
        sim.tick()
    churned = sim.pool.vehicles[cid].client.signal_handler.window(
        "Vehicle.Speed", 64
    )
    steady = sim.pool.vehicles["veh-000"].client.signal_handler.window(
        "Vehicle.Speed", 64
    )
    assert len(steady) == 10  # construction + 9 ticks, all observed
    assert len(churned) == 7  # the 3 ignition-off ticks are not "observed"


# --------------------------------------------------------------------- #
# bugfix: mass admission is amortized (geometric capacity growth)        #
# --------------------------------------------------------------------- #
def test_mass_admission_regrows_series_only_o_log_n_times():
    """Every series regrow is an XLA recompile for jit scenarios; joining
    28 vehicles one at a time must trigger O(log N) regrows, not 28."""
    scen = Scenario("urban", seed=1)
    regrows = []

    def counting_grow(n):
        regrows.append(n)
        return scen.series(n)

    plane = FleetSignalPlane(
        SIGNALS, scen.series(4), history=32, grow_fn=counting_grow
    )
    plane.step()
    before = plane.values.copy()
    rows = [plane.add_client() for _ in range(28)]
    assert rows == list(range(4, 32)) and plane.n_clients == 32
    assert len(regrows) <= 4  # 4 -> 8 -> 16 -> 32
    # row stability: existing vehicles' streams are untouched
    assert np.array_equal(plane.values[:4], before)
    # a freshly-joined row's history starts at the join tick, not before
    assert len(plane.window(31, "Vehicle.Speed", 32)) == 1
    plane.step()
    assert len(plane.window(31, "Vehicle.Speed", 32)) == 2
    # and the whole live fleet reads valid values post-join
    assert all(plane.read(i, "Vehicle.Speed") is not None for i in range(32))


def test_add_clients_batch_reserves_capacity_once():
    scen = Scenario("highway", seed=7)
    regrows = []

    def counting_grow(n):
        regrows.append(n)
        return scen.series(n)

    plane = FleetSignalPlane(
        SIGNALS, scen.series(2), history=16, grow_fn=counting_grow
    )
    assert plane.add_clients(30) == list(range(2, 32))
    assert plane.n_clients == 32 and len(regrows) == 1


def test_fixed_size_plane_still_rejects_growth():
    plane = FleetSignalPlane.from_csv_fleet(["a\n1\n2\n"])
    with pytest.raises(ValueError, match="fixed fleet size"):
        plane.add_client()


def test_spare_capacity_rows_are_not_readable():
    # overallocation must not expose phantom vehicles: step() computes all
    # capacity rows, but reads past n_clients fail fast, as pre-growth
    scen = Scenario("highway", seed=7)
    plane = FleetSignalPlane(
        SIGNALS, scen.series(2), history=16, grow_fn=scen.series
    )
    for _ in range(3):  # single joins double capacity: n_clients=5, cap 8
        plane.add_client()
    plane.step()
    assert plane.n_clients == 5 and plane._capacity > 5
    for bad in (5, plane._capacity - 1, -1):
        with pytest.raises(IndexError, match="out of range"):
            plane.read(bad, SIGNALS[0])
        with pytest.raises(IndexError, match="out of range"):
            plane.window(bad, SIGNALS[0], 4)
        with pytest.raises(IndexError, match="out of range"):
            plane.view(bad)
        with pytest.raises(IndexError, match="out of range"):
            plane.set_online(bad, False)
    assert plane.read(4, SIGNALS[0]) is not None  # live rows still fine


# --------------------------------------------------------------------- #
# simulator determinism with the plane enabled                           #
# --------------------------------------------------------------------- #
def test_simulator_with_time_varying_scenario_is_deterministic():
    cfg = SimConfig(
        n_clients=12, seed=21, scenario="mixed", p_drop=0.1, max_delay=1
    )
    fed = FedConfig(
        local_steps=2, local_lr=0.2, deadline_fraction=0.8, deadline_pumps=32
    )

    def run():
        sim = FleetSimulator(cfg)
        drv = sim.run_federated(fed, dim=8, rounds=2, n_samples=8)
        return drv.w.copy(), sim.plane.values.copy()

    (w1, v1), (w2, v2) = run(), run()
    assert np.array_equal(w1, w2)
    assert np.array_equal(v1, v2)


def test_simulator_default_uses_plane_and_legacy_signal_fn_still_works():
    from repro.core.signals import constant

    sim = FleetSimulator(SimConfig(n_clients=4, seed=0))
    assert sim.plane is not None and sim.pool.plane is sim.plane
    legacy = FleetSimulator(
        SimConfig(n_clients=4, seed=0),
        signal_fn=lambda i: {"Vehicle.RoadGrade": constant(0.5)},
    )
    assert legacy.plane is None
    legacy.tick()  # the per-vehicle iterator path still ticks fine
    v = next(iter(legacy.pool.vehicles.values()))
    assert SignalHandler(v.signals).get("Vehicle.RoadGrade") == 0.5
