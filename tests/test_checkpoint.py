"""Durable fleet state: `FleetCheckpoint.save/restore` must be invisible
to the simulation — `run(a+b) == run(a) -> save -> restore -> run(b)`
bit-for-bit on aggregates, broker counters, participation/cancel/pump
counts, consumed ticks, and signal-plane reads — across faults × churn ×
stragglers × backends × {host, sharded} planes × {fedavg, analytics}
workloads, including checkpoints taken mid-round with tasks in flight.
Plus elastic resharding (8 devices -> 1/2/4) and the negative paths
(corrupt manifest, missing blob, schema bump, forbidden overrides)."""
import json

import numpy as np
import pytest

from repro.fleet import Backends, FedConfig, FleetSimulator, SimConfig
from repro.fleet.analytics import AnalyticsConfig, AnalyticsDriver
from repro.fleet.checkpoint import (
    SCHEMA_VERSION,
    CheckpointError,
    FleetCheckpoint,
)
from repro.train.checkpoint import BlobStore

ENGINE = dict(engine="event", service="scheduler", churn="event")
CALENDAR = dict(engine="event", service="calendar", churn="event")
DENSE = dict(engine="dense", service="dense", churn="dense")

GRID = {
    "clean": {},
    "faults": dict(p_drop=0.15, p_duplicate=0.05, max_delay=2),
    "churn": dict(p_leave=0.05, p_return=0.3),
    "stragglers": dict(straggler_fraction=0.25, straggler_period=8),
    "everything": dict(
        p_drop=0.15, p_duplicate=0.05, max_delay=2, p_leave=0.02,
        p_return=0.3, straggler_fraction=0.25, straggler_period=8,
    ),
}

FED = FedConfig(
    local_steps=2, local_lr=0.2, deadline_fraction=0.7, deadline_pumps=48
)
ANA = AnalyticsConfig(deadline_fraction=0.7, deadline_pumps=32)


def _cfg(backends, **overrides):
    knobs = dict(n_clients=32, seed=17)
    knobs.update(overrides)
    return SimConfig(backends=Backends(**backends), **knobs)


# --------------------------------------------------------------------- #
# fingerprints: everything the golden contract pins down                 #
# --------------------------------------------------------------------- #
def _np_default(o):
    if isinstance(o, np.ndarray):
        return o.tolist()
    if isinstance(o, np.generic):
        return o.item()
    raise TypeError(f"not fingerprintable: {o!r}")


def _dump(fp) -> str:
    # json round-trips float reprs exactly and renders NaN stably, so
    # string equality is bit-for-bit equality (wall_s is never included)
    return json.dumps(fp, default=_np_default, sort_keys=True)


def _plane_probe(sim):
    p = sim.plane
    name = p.names[0]
    rows = min(4, p.n_clients)
    return {
        "t": p.t,
        "values": [float(p.read(i, name)) for i in range(rows)],
        "window": [np.asarray(p.window(i, name, 8)).tolist()
                   for i in range(rows)],
    }


def _fed_fp(sim, drv):
    return {
        "w": drv.w,
        "history": drv.history,
        "broker": [sim.broker.published, sim.broker.delivered,
                   sim.broker.dropped],
        "t": sim.t,
        "plane": _plane_probe(sim),
    }


def _ana_fp(sim, drv):
    return {
        "history": [
            {
                "window_id": r.window_id, "participants": r.participants,
                "canceled": r.canceled, "pumps": r.pumps, "count": r.count,
                "mean": r.mean, "var": r.var, "hist": r.hist,
                "q_values": r.q_values, "q_weights": r.q_weights,
            }
            for r in drv.history
        ],
        "broker": [sim.broker.published, sim.broker.delivered,
                   sim.broker.dropped],
        "t": sim.t,
        "plane": _plane_probe(sim),
    }


# --------------------------------------------------------------------- #
# the tentpole contract: run(a+b) == run(a) -> save/restore -> run(b)    #
# --------------------------------------------------------------------- #
def _golden_federated(tmp_path, backends, knobs, *, split=2, extra=2):
    total = split + extra
    simA = FleetSimulator(_cfg(backends, **knobs))
    drvA = simA.run_federated(FED, dim=16, rounds=total, n_samples=8)
    want = _dump(_fed_fp(simA, drvA))

    simB = FleetSimulator(_cfg(backends, **knobs))
    drvB = simB.run_federated(FED, dim=16, rounds=split, n_samples=8)
    FleetCheckpoint.save(simB, tmp_path / "ck", driver=drvB)
    simC, drvC, rif = FleetCheckpoint.restore(tmp_path / "ck")
    assert rif is None
    drvC = simC.run_federated(FED, rounds=extra, driver=drvC)
    assert _dump(_fed_fp(simC, drvC)) == want


def _golden_analytics(tmp_path, backends, knobs, *, split=2, extra=2):
    total = split + extra
    knobs = dict(knobs, scenario="mixed")
    simA = FleetSimulator(_cfg(backends, **knobs))
    drvA = simA.run_analytics(ANA, windows=total, warmup_ticks=6)
    want = _dump(_ana_fp(simA, drvA))

    simB = FleetSimulator(_cfg(backends, **knobs))
    drvB = simB.run_analytics(ANA, windows=split, warmup_ticks=6)
    FleetCheckpoint.save(simB, tmp_path / "ck", driver=drvB)
    simC, drvC, rif = FleetCheckpoint.restore(tmp_path / "ck")
    assert rif is None
    drvC = simC.run_analytics(ANA, windows=extra, driver=drvC)
    assert _dump(_ana_fp(simC, drvC)) == want


@pytest.mark.parametrize("backends", [ENGINE, CALENDAR, DENSE], ids=["engine", "calendar", "dense"])
@pytest.mark.parametrize("scenario", sorted(GRID))
def test_golden_restore_federated(scenario, backends, tmp_path):
    _golden_federated(tmp_path, backends, GRID[scenario])


@pytest.mark.parametrize("backends", [ENGINE, CALENDAR, DENSE], ids=["engine", "calendar", "dense"])
@pytest.mark.parametrize("scenario", ["clean", "everything"])
def test_golden_restore_analytics(scenario, backends, tmp_path):
    _golden_analytics(tmp_path, backends, GRID[scenario])


@pytest.mark.parametrize("workload", ["federated", "analytics"])
def test_golden_restore_sharded_plane(workload, tmp_path):
    knobs = dict(GRID["everything"], n_clients=16, plane="sharded")
    if workload == "federated":
        _golden_federated(tmp_path, ENGINE, knobs)
    else:
        _golden_analytics(tmp_path, ENGINE, knobs)


def test_checkpoint_at_tick_zero(tmp_path):
    """Saving the freshly built world (before any round) restores to the
    same full run — the boundary case a naive 'after round N' format
    cannot express."""
    knobs = GRID["everything"]
    simA = FleetSimulator(_cfg(ENGINE, **knobs))
    drvA = simA.run_federated(FED, dim=16, rounds=2, n_samples=8)
    want = _dump(_fed_fp(simA, drvA))

    simB = FleetSimulator(_cfg(ENGINE, **knobs))
    FleetCheckpoint.save(simB, tmp_path / "ck")
    simC, drvC, rif = FleetCheckpoint.restore(tmp_path / "ck")
    assert drvC is None and rif is None
    drvC = simC.run_federated(FED, dim=16, rounds=2, n_samples=8)
    assert _dump(_fed_fp(simC, drvC)) == want


# --------------------------------------------------------------------- #
# mid-round: tasks in flight when the world freezes                      #
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backends", [ENGINE, CALENDAR, DENSE], ids=["engine", "calendar", "dense"])
@pytest.mark.parametrize("steps", [0, 3])
def test_midround_checkpoint_federated(backends, steps, tmp_path):
    knobs = GRID["everything"]
    simA = FleetSimulator(_cfg(backends, **knobs))
    drvA = simA.run_federated(FED, dim=16, rounds=3, n_samples=8)
    want = _dump(_fed_fp(simA, drvA))

    simB = FleetSimulator(_cfg(backends, **knobs))
    drvB = simB.run_federated(FED, dim=16, rounds=2, n_samples=8)
    rif = drvB.start_round(2, simB.tick)
    for _ in range(steps):
        rif.pump.step()
    FleetCheckpoint.save(simB, tmp_path / "ck", driver=drvB, rif=rif)
    simC, drvC, rifC = FleetCheckpoint.restore(tmp_path / "ck")
    assert rifC is not None and rifC.rnd == 2
    # step() goes idempotent once the round closes, so compare against
    # the live pump's actual progress, not the requested step count
    assert rifC.pump.pumps == rif.pump.pumps
    assert rifC.pump.closed == rif.pump.closed
    drvC.finish_round(rifC)
    got = _fed_fp(simC, drvC)
    # metrics rows for the interrupted round are recorded by the campaign
    # loop, not finish_round — compare the driver-level observables
    assert _dump(got["history"]) == _dump([r for r in drvA.history])
    assert _dump(got["w"]) == _dump(drvA.w)
    assert got["t"] == simA.t and _dump(got["plane"]) == _dump(
        _plane_probe(simA)
    )
    assert got["broker"] == [simA.broker.published, simA.broker.delivered,
                             simA.broker.dropped]
    assert _dump(got) == want


@pytest.mark.parametrize("backends", [ENGINE, CALENDAR, DENSE], ids=["engine", "calendar", "dense"])
def test_midround_checkpoint_analytics(backends, tmp_path):
    knobs = dict(GRID["everything"], scenario="mixed")
    simA = FleetSimulator(_cfg(backends, **knobs))
    drvA = simA.run_analytics(ANA, windows=3, warmup_ticks=6)
    want = _dump(_ana_fp(simA, drvA))

    simB = FleetSimulator(_cfg(backends, **knobs))
    drvB = simB.run_analytics(ANA, windows=2, warmup_ticks=6)
    wif = drvB.start_window(2, simB.tick)
    for _ in range(3):
        wif.pump.step()
    FleetCheckpoint.save(simB, tmp_path / "ck", driver=drvB, rif=wif)
    simC, drvC, wifC = FleetCheckpoint.restore(tmp_path / "ck")
    assert isinstance(drvC, AnalyticsDriver)
    assert wifC is not None and wifC.window_id == 2
    drvC.finish_window(wifC)
    assert _dump(_ana_fp(simC, drvC)) == want


# --------------------------------------------------------------------- #
# elastic resharding: save on 8 devices, restore on 1/2/4                #
# --------------------------------------------------------------------- #
def _device_count() -> int:
    import jax

    return jax.device_count()


@pytest.mark.skipif(
    _device_count() < 8,
    reason="elastic resharding needs 8 simulated devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)",
)
@pytest.mark.parametrize("devices", [1, 2, 4])
def test_elastic_restore_onto_fewer_devices(devices, tmp_path):
    """A checkpoint taken with the plane sharded over 8 devices restores
    onto 1/2/4 and stays bit-for-bit with the host oracle — resharding
    re-pads the ring and re-places device arrays, reads are unchanged."""
    import jax

    from repro.sharding.fleet import client_mesh

    knobs = dict(GRID["everything"], n_clients=16)
    host = FleetSimulator(_cfg(ENGINE, plane="host", **knobs))
    drvH = host.run_federated(FED, dim=16, rounds=4, n_samples=8)
    want = _dump(_fed_fp(host, drvH))

    sim = FleetSimulator(_cfg(ENGINE, plane="sharded", **knobs))
    assert sim.plane.devices == 8
    drv = sim.run_federated(FED, dim=16, rounds=2, n_samples=8)
    FleetCheckpoint.save(sim, tmp_path / "ck", driver=drv)

    mesh = client_mesh(jax.devices()[:devices])
    simR, drvR, _ = FleetCheckpoint.restore(tmp_path / "ck", mesh=mesh)
    assert simR.plane.devices == devices
    # plane parity right at the restore point, before any further tick
    assert _dump(_plane_probe(simR)) == _dump(_plane_probe(sim))
    drvR = simR.run_federated(FED, rounds=2, driver=drvR)
    assert _dump(_fed_fp(simR, drvR)) == want


def test_mesh_requires_a_sharded_checkpoint(tmp_path):
    from repro.sharding.fleet import client_mesh

    sim = FleetSimulator(_cfg(ENGINE, n_clients=8))
    FleetCheckpoint.save(sim, tmp_path / "ck")
    with pytest.raises(CheckpointError, match="mesh="):
        FleetCheckpoint.restore(tmp_path / "ck", mesh=client_mesh())


# --------------------------------------------------------------------- #
# negative paths: every failure names the file/field, nothing partial    #
# --------------------------------------------------------------------- #
@pytest.fixture()
def saved(tmp_path):
    sim = FleetSimulator(_cfg(ENGINE, n_clients=8, **GRID["faults"]))
    drv = sim.run_federated(FED, dim=8, rounds=1, n_samples=4)
    FleetCheckpoint.save(sim, tmp_path / "ck", driver=drv)
    return tmp_path / "ck"


def test_restore_missing_manifest(tmp_path):
    with pytest.raises(CheckpointError, match="manifest missing") as ei:
        FleetCheckpoint.restore(tmp_path / "nope")
    assert str(tmp_path / "nope" / "manifest.json") in str(ei.value)


def test_restore_corrupt_manifest(saved):
    (saved / "manifest.json").write_text("{not json")
    with pytest.raises(CheckpointError, match="manifest corrupt") as ei:
        FleetCheckpoint.restore(saved)
    assert "manifest.json" in str(ei.value)


def test_restore_schema_version_bump(saved):
    mpath = saved / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["schema"] = SCHEMA_VERSION + 1
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(
        CheckpointError,
        match=rf"schema version {SCHEMA_VERSION + 1}.*reads {SCHEMA_VERSION}",
    ) as ei:
        FleetCheckpoint.restore(saved)
    assert "manifest.json" in str(ei.value)


def test_restore_wrong_format_tag(saved):
    mpath = saved / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["format"] = "something-else"
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(CheckpointError, match="format 'something-else'"):
        FleetCheckpoint.restore(saved)


def test_restore_missing_blob_leaf(saved):
    leaf = sorted((saved / "arrays").glob("*.npy"))[0]
    leaf.unlink()
    with pytest.raises(CheckpointError, match="leaf missing") as ei:
        FleetCheckpoint.restore(saved)
    assert leaf.name in str(ei.value)


def test_restore_corrupt_blob_leaf(saved):
    leaf = sorted((saved / "arrays").glob("*.npy"))[0]
    leaf.write_bytes(b"\x93NUMPY garbage")
    with pytest.raises(CheckpointError, match="sha256"):
        FleetCheckpoint.restore(saved)


def test_structural_overrides_are_rejected(tmp_path):
    """A sharded checkpoint cannot be restored as plane=host by override
    — the saved device ring has no host twin; mesh= is the supported way
    to change the device layout."""
    sim = FleetSimulator(_cfg(ENGINE, n_clients=8, plane="sharded"))
    FleetCheckpoint.save(sim, tmp_path / "ck")
    with pytest.raises(CheckpointError, match=r"'plane'.*mesh=") as ei:
        FleetCheckpoint.restore(
            tmp_path / "ck", config_overrides={"plane": "host"}
        )
    assert "manifest.json" in str(ei.value)
    with pytest.raises(CheckpointError, match="'n_clients'"):
        FleetCheckpoint.restore(
            tmp_path / "ck", config_overrides={"n_clients": 16}
        )


def test_fault_overrides_are_allowed(saved):
    """Non-structural knobs may deliberately diverge on restore — e.g.
    replaying the same world under heavier faults."""
    sim, drv, _ = FleetCheckpoint.restore(
        saved, config_overrides={"p_drop": 0.5}
    )
    assert sim.cfg.p_drop == 0.5
    sim.run_federated(FED, rounds=1, driver=drv)  # still runs


def test_save_rejects_rif_without_driver(tmp_path):
    sim = FleetSimulator(_cfg(ENGINE, n_clients=8))
    with pytest.raises(CheckpointError, match="without its driver"):
        FleetCheckpoint.save(sim, tmp_path / "ck", rif=object())


# --------------------------------------------------------------------- #
# BlobStore: deterministic, content-addressed, self-verifying            #
# --------------------------------------------------------------------- #
def test_blobstore_roundtrip_is_deterministic(tmp_path):
    tree = {
        "w": np.arange(6, dtype=np.float32).reshape(2, 3),
        "nested": {"m": np.eye(2), "none": None},
        "seq": [np.float64(1.5), (np.int32(2), np.arange(3))],
    }
    store = BlobStore(tmp_path / "blobs")
    store.put("state", tree)
    first = (tmp_path / "blobs" / "state.json").read_text()
    store.put("state", tree)  # identical re-save writes identical bytes
    assert (tmp_path / "blobs" / "state.json").read_text() == first

    out = store.get("state")
    assert np.array_equal(out["w"], tree["w"])
    assert np.array_equal(out["nested"]["m"], np.eye(2))
    assert out["nested"]["none"] is None
    assert isinstance(out["seq"][1], tuple)
    assert np.array_equal(out["seq"][1][1], np.arange(3))


def test_blobstore_dedups_identical_leaves(tmp_path):
    store = BlobStore(tmp_path / "blobs")
    a = np.ones((4, 4), np.float32)
    store.put("x", [a, a.copy(), {"again": a}])
    assert len(list((tmp_path / "blobs").glob("*.npy"))) == 1


def test_blobstore_link_from_hardlinks_unchanged_leaves(tmp_path):
    prev = BlobStore(tmp_path / "prev")
    a = np.arange(16, dtype=np.float32)
    b = np.ones(8, np.float64)
    prev.put("x", {"a": a, "b": b})
    nxt = BlobStore(tmp_path / "next")
    nxt.put("x", {"a": a, "b": b + 1}, link_from=prev)
    inode = {p.name: p.stat().st_ino for p in (tmp_path / "prev").glob("*.npy")}
    for p in (tmp_path / "next").glob("*.npy"):
        if p.name in inode:  # unchanged leaf: same inode, not a rewrite
            assert p.stat().st_ino == inode[p.name], p.name
    # exactly one leaf (b+1) is new to the next store
    new = {p.name for p in (tmp_path / "next").glob("*.npy")} - set(inode)
    assert len(new) == 1
    out = nxt.get("x")
    assert np.array_equal(out["a"], a)
    assert np.array_equal(out["b"], b + 1)


# --------------------------------------------------------------------- #
# incremental fleet saves: unchanged arrays hardlink to the previous     #
# checkpoint; identical states produce identical manifests               #
# --------------------------------------------------------------------- #
def test_incremental_fleet_checkpoint_reuses_inodes(tmp_path):
    sim = FleetSimulator(_cfg(CALENDAR, **GRID["everything"]))
    drv = sim.run_federated(FED, dim=16, rounds=1, n_samples=8)
    FleetCheckpoint.save(sim, tmp_path / "ck0", driver=drv)
    drv = sim.run_federated(FED, rounds=1, driver=drv)
    FleetCheckpoint.save(sim, tmp_path / "ck1", driver=drv,
                         previous=tmp_path / "ck0")
    prev = {p.name: p.stat().st_ino
            for p in (tmp_path / "ck0" / "arrays").glob("*.npy")}
    shared = 0
    for p in (tmp_path / "ck1" / "arrays").glob("*.npy"):
        if p.name in prev:
            assert p.stat().st_ino == prev[p.name], p.name
            shared += 1
    # plenty of per-client state is untouched between adjacent rounds
    assert shared > 0
    # a same-state re-save produces a byte-identical manifest
    FleetCheckpoint.save(sim, tmp_path / "ck1b", driver=drv,
                         previous=tmp_path / "ck1")
    assert (
        (tmp_path / "ck1" / "manifest.json").read_bytes()
        == (tmp_path / "ck1b" / "manifest.json").read_bytes()
    )
    # and the incremental chain still restores bit-for-bit
    sim2, drv2, _ = FleetCheckpoint.restore(tmp_path / "ck1")
    assert _dump(_fed_fp(sim2, drv2)) == _dump(_fed_fp(sim, drv))


# --------------------------------------------------------------------- #
# property test: random knobs + random checkpoint tick (graceful skip)   #
# --------------------------------------------------------------------- #
def _property_golden(seed, n, p_drop, p_dup, delay, p_leave, p_return,
                     frac, split, tmp_path):
    knobs = dict(
        n_clients=n, seed=seed, p_drop=p_drop, p_duplicate=p_dup,
        max_delay=delay, p_leave=p_leave, p_return=p_return,
        straggler_fraction=frac,
    )
    fed = FedConfig(
        local_steps=1, local_lr=0.2, deadline_fraction=0.7,
        deadline_pumps=24,
    )
    total = 3
    simA = FleetSimulator(_cfg(ENGINE, **knobs))
    drvA = simA.run_federated(fed, dim=8, rounds=total, n_samples=4)
    want = _dump(_fed_fp(simA, drvA))

    simB = FleetSimulator(_cfg(ENGINE, **knobs))
    drvB = None
    if split > 0:
        drvB = simB.run_federated(fed, dim=8, rounds=split, n_samples=4)
    ck = tmp_path / f"ck-{seed}-{split}"
    FleetCheckpoint.save(simB, ck, driver=drvB)
    simC, drvC, _ = FleetCheckpoint.restore(ck)
    if drvC is None:
        drvC = simC.run_federated(fed, dim=8, rounds=total, n_samples=4)
    elif total - split > 0:
        drvC = simC.run_federated(fed, rounds=total - split, driver=drvC)
    assert _dump(_fed_fp(simC, drvC)) == want


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # graceful skip — hypothesis is optional
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_random_worlds_restore_bit_for_bit():
        pass
else:
    @settings(
        max_examples=10,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(4, 16),
        p_drop=st.floats(0.0, 0.3),
        p_dup=st.floats(0.0, 0.2),
        delay=st.integers(0, 3),
        p_leave=st.floats(0.0, 0.1),
        p_return=st.floats(0.0, 0.5),
        frac=st.floats(0.0, 0.5),
        split=st.integers(0, 3),  # includes tick 0 and the final round
    )
    def test_random_worlds_restore_bit_for_bit(
        seed, n, p_drop, p_dup, delay, p_leave, p_return, frac, split,
        tmp_path_factory,
    ):
        _property_golden(
            seed, n, p_drop, p_dup, delay, p_leave, p_return, frac, split,
            tmp_path_factory.mktemp("golden"),
        )
