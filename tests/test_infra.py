"""Infrastructure units: blob store, data pipeline determinism, HLO
collective parsing, wire-format codecs."""
import jax.numpy as jnp
import numpy as np

from repro.configs import get_tiny
from repro.data.pipeline import host_shard, synthetic_batch
from repro.train.checkpoint import BlobStore


def test_blobstore_roundtrip(tmp_path):
    store = BlobStore(tmp_path)
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((2,), jnp.int32)},
    }
    store.put("ckpt-1", tree)
    back = store.get("ckpt-1")
    assert np.array_equal(back["a"], tree["a"])
    assert np.array_equal(back["b"]["c"], tree["b"]["c"])
    assert store.exists("ckpt-1") and not store.exists("ckpt-2")


def test_pipeline_deterministic_and_shardable():
    cfg = get_tiny("granite-8b")
    b1 = synthetic_batch(cfg, batch=8, seq=32, seed=7, step=3)
    b2 = synthetic_batch(cfg, batch=8, seq=32, seed=7, step=3)
    b3 = synthetic_batch(cfg, batch=8, seq=32, seed=7, step=4)
    assert np.array_equal(b1["tokens"], b2["tokens"])  # (seed, step) pure
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host shards tile the global batch
    shards = [host_shard(b1, i, 4)["tokens"] for i in range(4)]
    assert np.array_equal(np.concatenate(shards), b1["tokens"])
    # labels are next-token shifted with a masked tail
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
    assert (np.asarray(b1["labels"][:, -1]) == -1).all()


def test_pipeline_multimodal_shapes():
    vlm = get_tiny("internvl2-26b")
    b = synthetic_batch(vlm, batch=2, seq=32, seed=0, step=0)
    assert b["patch_embeds"].shape == (2, vlm.n_patches, vlm.d_model)
    au = get_tiny("musicgen-large")
    b = synthetic_batch(au, batch=2, seq=32, seed=0, step=0)
    assert b["frame_embeds"].shape == (2, 32, au.d_model)
    assert b["labels"].shape == (2, 32, au.n_codebooks)


def test_collective_parser():
    import pathlib

    # parse functions without executing module-level XLA device locking:
    src = pathlib.Path("src/repro/launch/dryrun.py").read_text()
    ns: dict = {}

    block = src[src.index("_DTYPE_BYTES") : src.index("def sharded_bytes")]
    exec("import re\n" + block, ns)
    hlo = """
  %all-gather.1 = f32[8,128]{1,0} all-gather(%x), replica_groups={}
  %all-reduce.2 = (bf16[4,4]{1,0}, bf16[4,4]{1,0}) all-reduce(%a, %b)
  %all-reduce-start.9 = f32[16]{0} all-reduce-start(%y)
  %all-reduce-done.9 = f32[16]{0} all-reduce-done(%q)
  %add.1 = f32[2]{0} add(%p, %q)
"""
    out = ns["collective_bytes"](hlo)
    assert out["all-gather"] == 8 * 128 * 4
    assert out["all-reduce"] == 2 * 16 * 2 + 16 * 4  # tuple + start, no -done
    assert out["total"] == out["all-gather"] + out["all-reduce"]
