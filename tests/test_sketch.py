"""Fused windowed-sketch kernels: parity with the per-vehicle oracle.

The contract under test (ISSUE/ROADMAP item 2): one fleet-wide device
fold over the signal ring (`compute_sketches`) must match the sandboxed
per-vehicle Python fold (`sketch_reference`, the `ANALYTICS_PAYLOAD`
formula) bit for bit — moments, histogram, and quantile values — across
offline-NaN masking, short histories, and fleet growth; sharded == host;
Pallas kernel == XLA twin; and the sharded analytics path must never
sync the ring to the host. Quantile *queries* after merging carry a
deterministic rank-error bound, pinned by a property test.
"""
import numpy as np
import pytest

from repro.fleet.analytics import AnalyticsConfig, WindowStats
from repro.fleet.scenarios import Scenario
from repro.fleet.simulator import FleetSimulator, SimConfig
from repro.kernels.ops import merge_quantile_sketches
from repro.kernels.sketch import (
    FleetSketches,
    SketchSpec,
    empty_fleet_sketches,
    fold_window,
    sketch_reference,
    sketches_from_device,
)

SIG = "Vehicle.FuelRate"


def _random_window(rng, W, n):
    """A (W, n) time-ordered window with the NaN patterns the ring
    produces: leading not-yet-observed prefixes, offline holes, and a
    fully-empty column."""
    x = rng.normal(5.0, 3.0, (W, n)).astype(np.float32)
    for j in range(n):
        x[: rng.integers(0, W + 1), j] = np.nan  # short history
    x[rng.random((W, n)) < 0.2] = np.nan         # offline ticks
    x[:, 0] = np.nan                             # never-observed client
    return x


def _rows_equal(sk, x, spec):
    for j in range(x.shape[1]):
        xs = [float(v) for v in x[:, j] if not np.isnan(v)]
        assert sk.row(j) == sketch_reference(xs, spec), f"column {j}"


# --------------------------------------------------------------------- #
# kernel-level parity                                                   #
# --------------------------------------------------------------------- #
def test_fold_window_matches_reference_bit_for_bit():
    rng = np.random.default_rng(0)
    spec = SketchSpec(window=37, bins=16, quantile_k=8)
    for _ in range(3):
        x = _random_window(rng, 37, 23)
        out = np.asarray(fold_window(x, spec, backend="xla"))
        assert out.shape == (spec.dim, 23)
        _rows_equal(sketches_from_device(spec, out), x, spec)


def test_pallas_kernel_matches_xla_twin():
    rng = np.random.default_rng(1)
    for spec in (
        SketchSpec(window=24, bins=16, quantile_k=8),
        SketchSpec(window=24, bins=1, quantile_k=4),  # no interior edges
    ):
        # 150 columns: exercises the NaN padding to a 128-client block
        x = _random_window(rng, spec.window, 150)
        a = np.asarray(fold_window(x, spec, backend="xla"))
        b = np.asarray(fold_window(x, spec, backend="pallas"))
        assert np.array_equal(a, b, equal_nan=True)
        _rows_equal(sketches_from_device(spec, b), x, spec)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        fold_window(np.zeros((2, 2), np.float32), SketchSpec(), backend="tdigest")


def test_spec_validation():
    with pytest.raises(ValueError):
        SketchSpec(window=0)
    with pytest.raises(ValueError):
        SketchSpec(bins=0)
    with pytest.raises(ValueError):
        SketchSpec(quantile_k=0)


# --------------------------------------------------------------------- #
# plane-level parity: compute_sketches vs the window() oracle           #
# --------------------------------------------------------------------- #
def _exercise(plane, rng, ticks=20, grow_at=9):
    n = plane.n_clients
    for t in range(ticks):
        plane.step()
        for i in rng.integers(0, n, 4):
            plane.set_online(int(i), bool(rng.random() < 0.5))
        if t == grow_at:
            plane.add_client()
            n = plane.n_clients
    return n


def _assert_matches_window_oracle(plane, spec):
    sk = plane.compute_sketches(SIG, spec)
    for i in range(plane.n_clients):
        ref = sketch_reference(plane.window(i, SIG, spec.window), spec)
        assert sk.row(i) == ref, f"row {i}"
    return sk


def test_host_plane_sketches_match_window_oracle():
    """Offline-NaN masking, short history (window > ring > observed),
    and mid-run fleet growth all reproduce the per-row fold exactly."""
    plane = Scenario("mixed", seed=3).plane(24, history=16)
    _exercise(plane, np.random.default_rng(2))
    # window larger than the ring: clamps like window() does
    for spec in (SketchSpec(window=8, quantile_k=8), SketchSpec(window=64, quantile_k=8)):
        _assert_matches_window_oracle(plane, spec)


def test_host_plane_short_history():
    plane = Scenario("urban", seed=4).plane(8, history=32)
    plane.step()  # hist_len = 2 << window
    _assert_matches_window_oracle(plane, SketchSpec(window=16, quantile_k=4))


def test_sharded_matches_host_and_ring_stays_on_device():
    scen = Scenario("mixed", seed=5)
    host, shard = scen.plane(24, history=16), scen.sharded_plane(24, history=16)
    ra, rb = np.random.default_rng(7), np.random.default_rng(7)
    _exercise(host, ra)
    _exercise(shard, rb)
    spec = SketchSpec(window=12, quantile_k=8)
    hs = _assert_matches_window_oracle(host, spec)

    shard.step()  # leave the ring dirty again after the oracle's window() sync
    host.step()
    syncs0 = shard.ring_syncs
    ss = shard.compute_sketches(SIG, spec)
    hs = host.compute_sketches(SIG, spec)
    # the analytics fast path never moves the ring device->host
    assert shard._hist_dirty and shard.ring_syncs == syncs0
    for field in ("counts", "means", "m2s", "hists"):
        assert np.array_equal(getattr(hs, field), getattr(ss, field)), field
    assert np.array_equal(hs.qvals, ss.qvals, equal_nan=True)


def test_sharded_pallas_backend_matches_xla():
    """The shard_mapped Pallas kernel (interpret mode off-TPU) agrees
    with the sharding-propagated XLA twin on every shard."""
    shard = Scenario("highway", seed=6).sharded_plane(24, history=16)
    for _ in range(10):
        shard.step()
    spec = SketchSpec(window=8, quantile_k=8)
    a = shard.compute_sketches(SIG, spec, backend="xla")
    b = shard.compute_sketches(SIG, spec, backend="pallas")
    for field in ("counts", "means", "m2s", "hists"):
        assert np.array_equal(getattr(a, field), getattr(b, field)), field
    assert np.array_equal(a.qvals, b.qvals, equal_nan=True)


def test_unknown_signal_folds_to_empty_sketch():
    plane = Scenario("idle", seed=0).plane(4, history=8)
    sk = plane.compute_sketches("No.Such.Signal", SketchSpec(window=4))
    assert sk.row(0) == sketch_reference([], SketchSpec(window=4))
    assert plane.sketch_row(2, "No.Such.Signal", SketchSpec(window=4))["count"] == 0


def test_sketch_row_cache_is_per_tick_fleet_wide():
    plane = Scenario("mixed", seed=8).plane(8, history=16)
    for _ in range(6):
        plane.step()
    spec = SketchSpec(window=4, quantile_k=4)
    sk = plane.compute_sketches(SIG, spec)
    assert plane.sketch_row(0, SIG, spec) == sk.row(0)
    plane.sketch_row(5, SIG, spec)
    assert len(plane._sketch_cache) == 1  # one fold served both rows
    plane.step()
    stale = plane.sketch_row(0, SIG, spec)
    assert len(plane._sketch_cache) == 1  # old tick evicted, not retained
    assert stale == sketch_reference(plane.window(0, SIG, 4), spec)
    # growth changes n_clients -> new key even at the same tick
    plane.add_client()
    plane.sketch_row(plane.n_clients - 1, SIG, spec)
    assert len(plane._sketch_cache) == 1


def test_empty_fleet_sketches_shapes():
    sk = empty_fleet_sketches(SketchSpec(bins=4, quantile_k=2), 3)
    assert isinstance(sk, FleetSketches) and sk.n_clients == 3
    assert sk.hists.shape == (3, 4) and sk.qvals.shape == (3, 2)


# --------------------------------------------------------------------- #
# payload API: get_signal_sketch fallback == reference                  #
# --------------------------------------------------------------------- #
def test_payload_sketch_fallback_matches_reference():
    from repro.core.payload_api import PayloadContext

    xs = [1.0, 2.5, 11.0, -3.0, 2.5]

    ctx = PayloadContext(
        get_signal=lambda name: xs[-1],
        get_signal_window=lambda name, k: xs[-k:],
        publish=lambda v: None,
    )
    got = ctx.get_signal_sketch("Vehicle.Speed", 5, bins=8, quantile_k=4)
    assert got == sketch_reference(xs, SketchSpec(window=5, bins=8, quantile_k=4))
    # an injected sketch closure that declines (returns None) falls back
    ctx2 = PayloadContext(
        get_signal=lambda name: xs[-1],
        get_signal_window=lambda name, k: xs[-k:],
        get_signal_sketch=lambda *a: None,
        publish=lambda v: None,
    )
    assert ctx2.get_signal_sketch("Vehicle.Speed", 5, bins=8, quantile_k=4) == got


# --------------------------------------------------------------------- #
# the vectorized analytics driver mode, end to end                      #
# --------------------------------------------------------------------- #
def _run_analytics(sketch: bool, **cfg_kw):
    sim = FleetSimulator(
        SimConfig(
            n_clients=16,
            seed=11,
            scenario="mixed",
            p_drop=0.08,
            p_duplicate=0.05,
            max_delay=2,
            p_leave=0.03,
            p_return=0.3,
            straggler_fraction=0.25,
            **cfg_kw,
        )
    )
    driver = sim.run_analytics(
        AnalyticsConfig(sketch=sketch, window=16, quantile_k=8),
        windows=3,
        warmup_ticks=6,
    )
    return sim, driver


@pytest.mark.parametrize("plane", ["host", "sharded"])
def test_driver_sketch_mode_is_bit_for_bit_with_payload_oracle(plane):
    """`AnalyticsConfig(sketch=True)` — one fused device fold per tick —
    publishes the same sketches as the per-sandbox `ANALYTICS_PAYLOAD`
    fold under faults x churn x stragglers x offline masking, so the
    whole campaign (participation, cancels, merged stats, quantiles,
    broker traffic) is identical."""
    sa, da = _run_analytics(False, plane=plane)
    sb, db = _run_analytics(True, plane=plane)
    assert len(da.history) == len(db.history) == 3
    for ra, rb in zip(da.history, db.history):
        assert (ra.participants, ra.canceled, ra.pumps) == (
            rb.participants, rb.canceled, rb.pumps,
        )
        assert ra.count == rb.count
        assert ra.mean == rb.mean and ra.var == rb.var
        assert np.array_equal(ra.hist, rb.hist)
        assert np.array_equal(ra.q_values, rb.q_values, equal_nan=True)
        assert np.array_equal(ra.q_weights, rb.q_weights)
    assert (sa.broker.published, sa.broker.delivered, sa.broker.dropped) == (
        sb.broker.published, sb.broker.delivered, sb.broker.dropped,
    )


def test_driver_progress_gauge_tracks_status_counters():
    sim, driver = _run_analytics(True)
    p = sim.metrics.progress
    assert p is not None and p.round == 2
    last = driver.history[-1]
    assert p.total == last.participants + last.canceled
    assert p.finished == last.participants
    assert p.canceled == last.canceled
    assert p.terminal == p.total and p.active == 0
    assert p.completion == pytest.approx(last.participants / p.total)


# --------------------------------------------------------------------- #
# quantile queries over merged summaries                                #
# --------------------------------------------------------------------- #
def _stats_from_parts(parts, K):
    spec = SketchSpec(window=max(1, max(map(len, parts))), quantile_k=K)
    qvals = [
        (r["qsk"] or [np.nan] * K)
        for r in (sketch_reference(p, spec) for p in parts)
    ]
    counts = [len(p) for p in parts]
    v, w = merge_quantile_sketches(
        np.asarray(qvals, np.float32), np.asarray(counts, np.float32)
    )
    total = sum(counts)
    return WindowStats(
        0, len(parts), 0, 0, total, 0.0, 0.0,
        np.zeros(4, np.int64), q_values=v, q_weights=w,
    )


def test_single_sketch_quantiles_are_exact_order_statistics():
    data = np.arange(64, dtype=np.float32)
    ws = _stats_from_parts([data], K=64)  # K == n: every sample survives
    for q in (0.0, 0.25, 0.5, 0.9, 1.0):
        assert ws.quantile(q) == float(
            np.quantile(data, q, method="inverted_cdf")
        )


def test_quantile_of_empty_and_zero_count_fleets_is_nan():
    assert np.isnan(WindowStats(0, 0, 0, 0, 0, 0.0, 0.0, np.zeros(4)).quantile(0.5))
    ws = _stats_from_parts([np.array([], np.float32)], K=4)
    assert np.isnan(ws.quantile(0.5))


def test_zero_count_clients_do_not_shift_ranks():
    data = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    with_empty = _stats_from_parts(
        [data, np.array([], np.float32), np.array([], np.float32)], K=4
    )
    without = _stats_from_parts([data], K=4)
    for q in (0.0, 0.5, 1.0):
        assert with_empty.quantile(q) == without.quantile(q)


def _rank_error(data_sorted, est, q):
    n = len(data_sorted)
    r_lo = float(np.sum(data_sorted < est))
    r_hi = float(np.sum(data_sorted <= est))
    target = q * n
    return max(0.0, r_lo - target, target - r_hi)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # graceful skip — hypothesis is optional
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_merged_partitions_hold_the_rank_error_bound():
        pass
else:
    @settings(
        max_examples=60,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(1, 400),
        n_parts=st.integers(1, 8),
        K=st.sampled_from([4, 8, 16, 32]),
    )
    def test_merged_partitions_hold_the_rank_error_bound(seed, n, n_parts, K):
        """Merging any random partition of a sample into K-point
        summaries answers every quantile within rank error
        n/(2K) + n_parts of the exact sorted-array percentile — the
        KLL-style guarantee the fused sketch path rests on."""
        rng = np.random.default_rng(seed)
        data = rng.normal(0.0, 10.0, n).astype(np.float32)
        cuts = np.sort(rng.integers(0, n + 1, n_parts - 1))
        parts = np.split(data, cuts)
        ws = _stats_from_parts(parts, K)
        srt = np.sort(data)
        bound = n / (2 * K) + len(parts)
        for q in (0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0):
            err = _rank_error(srt, ws.quantile(q), q)
            assert err <= bound, (q, err, bound)
