"""Calendar-queue service lanes: the numpy `CalendarLane` fire sets must
reproduce the heap `EngineService`'s booking schedule exactly — unit
invariants, a randomized fire-order oracle (hypothesis when available,
a seeded sweep always), the full-simulation parity grid
CalendarService == EngineService == dense poll oracle, and a mostly-idle
N=10k tick-loop parity check."""
import numpy as np
import pytest

from repro.fleet import (
    Backends,
    EngineService,
    FedConfig,
    FleetSimulator,
    SimConfig,
)
from repro.fleet.analytics import AnalyticsConfig
from repro.fleet.engine import CalendarLane, CalendarService

ENGINE = dict(engine="event", service="scheduler", churn="event")
CALENDAR = dict(engine="event", service="calendar", churn="event")
DENSE = dict(engine="dense", service="dense", churn="dense")

GRID = {
    "clean": {},
    "faults": dict(p_drop=0.15, p_duplicate=0.05, max_delay=2),
    "churn": dict(p_leave=0.05, p_return=0.3),
    "stragglers": dict(straggler_fraction=0.25, straggler_period=8),
    "everything": dict(
        p_drop=0.15, p_duplicate=0.05, max_delay=2, p_leave=0.02,
        p_return=0.3, straggler_fraction=0.25, straggler_period=8,
    ),
}


# --------------------------------------------------------------------- #
# lane unit invariants                                                   #
# --------------------------------------------------------------------- #
def _collect(fired):
    def cb(idx, t):
        fired.append((t, sorted(int(i) for i in idx)))
    return cb


def test_periodic_lane_fires_every_member_once_per_period():
    fired = []
    lane = CalendarLane(4, _collect(fired), capacity=8)
    for i in (0, 3, 5):
        lane.set_member(i, True)
    for t in range(1, 13):
        due = lane.due(t)
        want = sorted(i for i in (0, 3, 5) if (t + i) % 4 == 0)
        assert sorted(int(i) for i in due) == want, t
        lane.fire(t)
    # 12 ticks / period 4 = 3 firings per member
    assert sum(len(ids) for _, ids in fired) == 9


def test_one_shot_lane_clears_membership_on_fire():
    fired = []
    lane = CalendarLane(3, _collect(fired), one_shot=True, capacity=8)
    lane.set_member(2, True)
    for t in range(1, 8):
        lane.fire(t)
    assert [ids for _, ids in fired if ids] == [[2]]  # fired exactly once
    assert not lane.member(2)


def test_lane_growth_preserves_membership():
    lane = CalendarLane(5, _collect([]), capacity=2)
    lane.set_member(1, True)
    lane.ensure(100)
    lane.set_member(77, True)
    assert lane.member(1) and lane.member(77) and not lane.member(50)
    due = sorted(int(i) for i in lane.due(4))  # (4+1)%5==0, (4+77)%5 != 0
    assert due == [1]


def test_set_member_grows_on_demand():
    lane = CalendarLane(7, _collect([]), capacity=1)
    lane.set_member(31, True)
    assert lane.member(31)


# --------------------------------------------------------------------- #
# fire-order oracle: lane fires == heap bookings over random schedules   #
# --------------------------------------------------------------------- #
def _oracle_parity(seed: int, period: int, n: int, ticks: int) -> None:
    """Random membership toggles between ticks; the lane's due set each
    tick must equal the heap service's fire set — every powered-on
    member i fires exactly when (t + i) % period == 0."""
    rng = np.random.default_rng(seed)
    fired = []
    lane = CalendarLane(period, _collect(fired), capacity=n)
    members = set()
    for t in range(1, ticks + 1):
        for i in rng.integers(0, n, size=rng.integers(0, 4)):
            i = int(i)
            on = bool(rng.integers(0, 2))
            lane.set_member(i, on)
            (members.add if on else members.discard)(i)
        want = sorted(i for i in members if (t + i) % period == 0)
        got = sorted(int(i) for i in lane.due(t))
        assert got == want, (seed, t)
        lane.fire(t)


@pytest.mark.parametrize("seed", range(8))
def test_lane_fire_order_matches_heap_oracle_seeded(seed):
    _oracle_parity(seed, period=int(3 + seed % 5), n=32, ticks=40)


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # graceful skip — hypothesis is optional
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_lane_fire_order_matches_heap_oracle():
        pass
else:
    @settings(
        max_examples=30,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 2**16),
        period=st.integers(1, 16),
        n=st.integers(1, 64),
        ticks=st.integers(1, 64),
    )
    def test_lane_fire_order_matches_heap_oracle(seed, period, n, ticks):
        _oracle_parity(seed, period, n, ticks)


# --------------------------------------------------------------------- #
# full-simulation parity: calendar == heap engine == dense poll oracle   #
# --------------------------------------------------------------------- #
def _fingerprint(sim, driver):
    return (
        driver.w.copy(),
        (sim.broker.published, sim.broker.delivered, sim.broker.dropped),
        [r["participants"] for r in driver.history],
        [r["canceled"] for r in driver.history],
        [r["pumps"] for r in driver.history],
        sim.t,
    )


def _run(backends: dict, **overrides):
    cfg = dict(n_clients=48, seed=17)
    cfg.update(overrides)
    sim = FleetSimulator(SimConfig(backends=Backends(**backends), **cfg))
    driver = sim.run_federated(
        FedConfig(
            local_steps=2, local_lr=0.2, deadline_fraction=0.7,
            deadline_pumps=48,
        ),
        dim=16,
        rounds=3,
        n_samples=8,
    )
    return _fingerprint(sim, driver)


def _assert_equal(a, b):
    assert np.array_equal(a[0], b[0])
    assert a[1:] == b[1:]


@pytest.mark.parametrize("scenario", sorted(GRID))
def test_calendar_matches_heap_service_bit_for_bit(scenario):
    knobs = GRID[scenario]
    cal = _run(CALENDAR, **knobs)
    _assert_equal(cal, _run(ENGINE, **knobs))
    _assert_equal(cal, _run(DENSE, **knobs))


@pytest.mark.parametrize("seed", [0, 7, 23])
def test_calendar_parity_across_seeds(seed):
    knobs = dict(
        GRID["everything"], seed=seed, n_clients=32, resync_period=8
    )
    _assert_equal(_run(CALENDAR, **knobs), _run(ENGINE, **knobs))


def test_calendar_analytics_parity():
    def run(backends):
        sim = FleetSimulator(SimConfig(
            n_clients=32, seed=5, scenario="mixed",
            backends=Backends(**backends), **GRID["everything"],
        ))
        drv = sim.run_analytics(
            AnalyticsConfig(deadline_fraction=0.7, deadline_pumps=32),
            windows=2, warmup_ticks=6,
        )
        return (
            [(r.window_id, r.participants, r.canceled, r.mean, r.var)
             for r in drv.history],
            (sim.broker.published, sim.broker.delivered, sim.broker.dropped),
            sim.t,
        )

    assert run(CALENDAR) == run(ENGINE)


def test_calendar_service_is_selected_and_is_an_engine_service():
    sim = FleetSimulator(SimConfig(
        n_clients=8, seed=0, backends=Backends(service="calendar"),
    ))
    assert isinstance(sim.service, CalendarService)
    assert isinstance(sim.service, EngineService)  # drop-in subclass


def test_calendar_requires_the_event_engine():
    with pytest.raises(ValueError, match="calendar"):
        FleetSimulator(SimConfig(
            n_clients=4, seed=0,
            backends=Backends(service="calendar", engine="dense"),
        ))


# --------------------------------------------------------------------- #
# mostly-idle mega-fleet: N=10k tick-loop parity                         #
# --------------------------------------------------------------------- #
def test_tick_loop_parity_at_10k():
    """30 mostly-idle ticks over a 10k fleet with churn and stragglers:
    the calendar and heap services must agree on every externally
    visible gauge and on the runnable/straggler columns themselves."""
    def run(service):
        sim = FleetSimulator(SimConfig(
            n_clients=10_000, seed=3, p_leave=0.0005, p_return=0.2,
            straggler_fraction=0.1, resync_period=64, signal_history=4,
            backends=Backends(service=service),
        ))
        for _ in range(30):
            sim.tick()
        return (
            sim.metrics.fleet_gauges(),
            (sim.broker.published, sim.broker.delivered,
             sim.broker.dropped),
            sim.columns.runnable[:10_000].tobytes(),
            sim.columns.straggler[:10_000].tobytes(),
            sorted(sim.service._due),
        )

    assert run("calendar") == run("scheduler")
