"""Fleet layer: compression, error feedback, federated rounds through the
platform, elastic dropout, checkpoint/restart of the training driver."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import User, make_platform
from repro.core.signals import constant
from repro.fleet import (
    ErrorFeedback,
    FedConfig,
    FederatedDriver,
    FleetPool,
    make_codec,
)
from repro.fleet.compression import flatten_pytree, unflatten_pytree

KEY = jax.random.PRNGKey(0)


def test_flatten_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3), "b": jnp.ones((4,))}
    flat, td, shp = flatten_pytree(tree)
    back = unflatten_pytree(flat, td, shp)
    assert jnp.array_equal(back["a"], tree["a"]) and jnp.array_equal(back["b"], tree["b"])


@pytest.mark.parametrize("codec", ["int8", "topk", "none"])
def test_codec_roundtrip_error_bounded(codec):
    x = jax.random.normal(KEY, (10_000,))
    c = make_codec(codec) if codec != "topk" else make_codec(codec, fraction=0.3)
    msg = c.encode(x)
    y = c.decode(msg)
    if codec == "none":
        assert jnp.allclose(x, y)
    elif codec == "int8":
        assert float(jnp.max(jnp.abs(x - y))) < float(jnp.max(jnp.abs(x))) / 64
    assert c.nbytes(msg) <= x.size * 4


def test_error_feedback_accumulates_residual():
    """With error feedback, the *sum* of decoded messages converges to the
    sum of true vectors even under aggressive top-k."""
    ef = ErrorFeedback(make_codec("topk", fraction=0.05))
    true_sum = jnp.zeros((1000,))
    decoded_sum = jnp.zeros((1000,))
    for i in range(30):
        g = jax.random.normal(jax.random.PRNGKey(i), (1000,))
        true_sum = true_sum + g
        decoded_sum = decoded_sum + ef.codec.decode(ef.compress(g))
    rel = float(jnp.linalg.norm(true_sum - decoded_sum) / jnp.linalg.norm(true_sum))
    assert rel < 0.6  # without EF this is ~1.0 (almost everything dropped)
    assert ef.compression_ratio > 5


def test_federated_rounds_converge_with_dropout_and_stragglers():
    store, broker, (server,) = make_platform()
    pool = FleetPool(
        store, broker, server, n_vehicles=6,
        signal_fn=lambda i: {"Vehicle.RoadGrade": constant(0.02 * i)},
    )
    user = User(server, broker)
    drv = FederatedDriver(
        user,
        FedConfig(local_steps=3, local_lr=0.2, deadline_fraction=0.7),
        dim=12,
        w_true=np.linspace(-1, 1, 12).astype(np.float32),
    )
    for rnd in range(4):
        rec = drv.run_round(rnd, pump=lambda: pool.pump(dropout_prob=0.05))
        assert rec["participants"] >= 1
    assert drv.history[-1]["dist_to_optimum"] < 0.6 * drv.history[0]["dist_to_optimum"]


def test_train_driver_preemption_and_restart(tmp_path):
    from repro.launch.train import Preempted, TrainRun

    run = TrainRun("qwen3-4b", tiny=True, batch=2, seq=32, workdir=str(tmp_path))
    with pytest.raises(Preempted):
        run.run(30, ckpt_every=10, log_every=10, preempt_at=25)
    run.host.shutdown()
    run2 = TrainRun(
        "qwen3-4b", tiny=True, batch=2, seq=32, workdir=str(tmp_path),
        platform=(run.store, run.broker, run.server),
        disk=run.disk, task_id=run.task_id,
    )
    state, start = run2.init_or_restore()
    assert start == 20  # last acknowledged checkpoint
    logs = run2.run(30, ckpt_every=10, log_every=10)
    assert logs[-1]["step"] == 30


def test_training_loss_decreases(tmp_path):
    from repro.launch.train import TrainRun

    run = TrainRun("gemma3-1b", tiny=True, batch=4, seq=64, workdir=str(tmp_path))
    logs = run.run(40, ckpt_every=50, log_every=5)
    first = np.mean([l["loss"] for l in logs[:2]])
    last = np.mean([l["loss"] for l in logs[-2:]])
    assert last < first - 0.2, (first, last)
