"""Pallas kernels vs ref.py oracles — interpret-mode sweeps over shapes
and dtypes (the kernels' TPU lowering is exercised by the dry-run target;
interpret mode executes the same kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.quantize import dequantize_int8, quantize_int8
from repro.kernels.ssm_scan import ssm_scan

KEY = jax.random.PRNGKey(3)


@pytest.mark.parametrize(
    "B,S,H,KV,D,window,dtype",
    [
        (2, 256, 8, 4, 64, None, jnp.float32),
        (1, 256, 4, 1, 32, None, jnp.float32),
        (2, 256, 8, 8, 64, 128, jnp.float32),
        (1, 512, 6, 2, 128, None, jnp.float32),
        (1, 256, 8, 4, 64, None, jnp.bfloat16),
        (1, 256, 4, 4, 64, 64, jnp.bfloat16),
    ],
)
def test_flash_kernel_sweep(B, S, H, KV, D, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D), dtype)
    out = flash_attention(
        q, k, v, causal=True, window=window, block_q=64, block_k=64,
        interpret=True,
    )
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    atol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=atol
    )


@pytest.mark.parametrize(
    "B,S,inner,state,block_inner,chunk",
    [
        (2, 64, 128, 16, 64, 32),
        (1, 128, 64, 8, 64, 64),
        (2, 128, 256, 16, 128, 128),
        (1, 64, 64, 4, 32, 16),
    ],
)
def test_ssm_kernel_sweep(B, S, inner, state, block_inner, chunk):
    ks = jax.random.split(KEY, 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, inner))) * 0.1
    Bm = jax.random.normal(ks[1], (B, S, state))
    Cm = jax.random.normal(ks[2], (B, S, state))
    x = jax.random.normal(ks[3], (B, S, inner))
    A = -jnp.exp(jax.random.normal(ks[4], (inner, state)) * 0.5)
    y = ssm_scan(
        dt, Bm, Cm, x, A, block_inner=block_inner, chunk=chunk, interpret=True
    )
    want, _ = ref.ssm_scan_ref(dt, Bm, Cm, x, A)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), atol=1e-4)


@pytest.mark.parametrize("rows,cols", [(256, 128), (512, 300), (1024, 64)])
def test_quantize_kernel_sweep(rows, cols):
    x = jax.random.normal(KEY, (rows, cols)) * 3.0
    q, s = quantize_int8(x, block_rows=min(256, rows), interpret=True)
    qr, sr = ref.quantize_int8_ref(x)
    assert jnp.array_equal(q, qr)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr)[:, 0], rtol=1e-6)
    # roundtrip error bounded by scale/2
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert (err <= np.asarray(sr) * 0.5 + 1e-6).all()


def test_flash_kernel_vs_xla_twin():
    """The Pallas kernel and the model's XLA path agree (same algorithm)."""
    from repro.models.attention import flash_attention as xla_flash

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, 256, 8, 64))
    k = jax.random.normal(ks[1], (2, 256, 4, 64))
    v = jax.random.normal(ks[2], (2, 256, 4, 64))
    a = flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    b = xla_flash(q, k, v, q_block=64, kv_block=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
