"""Property-based tests of the Algorithm-1 sync loop (hypothesis).

The paper's resiliency claims, made mechanical: under ANY interleaving of
  * user actions (assign / cancel),
  * client event-pump and op-execution steps,
  * dropped QoS-0 notifications,
  * RPC failures (including submit acks lost AFTER the server applied the
    write — the worst case for duplication),
  * client crashes/restarts (volatile state lost, LocalDisk survives),
the platform must converge once the network heals:
  I1  every task reaches a terminal state;
  I2  FINISHED tasks have exactly the results their payload published —
      nothing lost, nothing duplicated, in order;
  I3  per-client logical clocks only ever increase;
  I4  the client ends fully synced (no unacked results for terminal tasks).
"""
from __future__ import annotations

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core import (  # noqa: E402
    Broker,
    EdgeClient,
    FaultPlan,
    FlakyServer,
    LocalDisk,
    TaskStatus,
    User,
    make_platform,
)

PAYLOADS = [
    # (source, expected results, expected status)
    (
        "import autospada\nautospada.publish({'v': 1})\n",
        [{"v": 1}],
        TaskStatus.FINISHED,
    ),
    (
        "import autospada\nfor i in range(3):\n    autospada.publish({'i': i})\n",
        [{"i": 0}, {"i": 1}, {"i": 2}],
        TaskStatus.FINISHED,
    ),
    (
        "import autospada\nautospada.publish({'v': 1})\nraise ValueError('x')\n",
        [{"v": 1}],
        TaskStatus.ERROR,
    ),
    (
        "import autospada\n"
        "s = autospada.load_state()\n"
        "n = 0 if s is None else s['n']\n"
        "autospada.cache_state({'n': n + 1})\n"
        "autospada.publish({'n': n + 1})\n",
        None,  # restart-dependent: checked structurally
        TaskStatus.FINISHED,
    ),
]

event_st = st.one_of(
    st.tuples(st.just("assign"), st.integers(0, len(PAYLOADS) - 1)),
    st.tuples(st.just("cancel"), st.integers(0, 7)),
    st.tuples(st.just("poll"), st.just(0)),
    st.tuples(st.just("step"), st.just(0)),
    st.tuples(st.just("restart"), st.just(0)),
    st.tuples(st.just("fail_rpcs"), st.integers(1, 3)),
    st.tuples(st.just("drop_notifications"), st.integers(1, 2)),
)


@settings(
    max_examples=60,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
@given(events=st.lists(event_st, min_size=1, max_size=40))
def test_sync_loop_converges_under_chaos(events):
    drops = {"n": 0}
    faults = FaultPlan(drop=lambda m: _take(drops))
    store, broker, (server,) = make_platform(broker=Broker(faults))
    fail_budget = {"n": 0}
    flaky = FlakyServer(server, lambda method, i: _take(fail_budget))

    clocks: dict[str, int] = {}

    def watch(cid, clock):
        assert clock > clocks.get(cid, 0), "I3: clock must be monotone"
        clocks[cid] = clock

    store.watch_clocks(watch)

    disk = LocalDisk()
    client = EdgeClient("veh", flaky, broker, disk=disk)
    client.bootstrap()
    user = User(server, broker)
    assignments = []

    for ev, arg in events:
        if ev == "assign":
            payload = user.payload(PAYLOADS[arg][0])
            a = user.assignment(f"a{len(assignments)}", [user.task("veh", payload)])
            a.commit()
            assignments.append((a, arg))
        elif ev == "cancel" and assignments:
            a, _ = assignments[arg % len(assignments)]
            a.cancel()
        elif ev == "poll":
            client.poll()
        elif ev == "step":
            client.step()
        elif ev == "restart":
            client.shutdown()
            client = EdgeClient("veh", flaky, broker, disk=disk)
            client.bootstrap()
        elif ev == "fail_rpcs":
            fail_budget["n"] += arg
        elif ev == "drop_notifications":
            drops["n"] += arg

    # network heals; client dials in; world quiesces
    fail_budget["n"] = 0
    drops["n"] = 0
    client.resync()
    client.run_until_idle()
    client.resync()
    client.run_until_idle()

    for a, pidx in assignments:
        source, expected, status = PAYLOADS[pidx]
        task_id = a.tasks[0].task_id
        task = server.task(task_id)
        # I1: terminal
        assert task.status != TaskStatus.ACTIVE, "I1: task still active"
        results = [r.value for r in server.results(task_id)]
        if task.status == TaskStatus.FINISHED:
            if expected is not None:
                # I2: exactly-once, in order
                assert results == expected, "I2 violated"
            else:
                # restartable counter payload: monotone 'n', no dups
                ns = [r["n"] for r in results]
                assert ns == sorted(set(ns)), "I2 violated (restart payload)"
        elif task.status == TaskStatus.CANCELED:
            # canceled before/while running: recorded results must still be
            # a prefix of the payload's publications
            if expected is not None:
                assert results == expected[: len(results)]
    # I4: nothing left unacknowledged for terminal tasks
    for task_id in list(disk.unacked):
        assert server.task(task_id).status == TaskStatus.ACTIVE


def _take(budget: dict) -> bool:
    if budget["n"] > 0:
        budget["n"] -= 1
        return True
    return False


@settings(max_examples=30, deadline=None)
@given(
    n_results=st.integers(1, 5),
    fail_after=st.integers(1, 6),
)
def test_lost_submit_ack_never_duplicates(n_results, fail_after):
    """Submit applied server-side but ack lost => client retries => the
    (task_id, seq) idempotency must keep results exactly-once."""
    store, broker, (server,) = make_platform()
    calls = {"n": 0}

    def should_fail(method, i):
        if method == "submit":
            calls["n"] += 1
            return calls["n"] == fail_after
        return False

    flaky = FlakyServer(server, should_fail)
    client = EdgeClient("veh", flaky, broker)
    client.bootstrap()
    client.run_until_idle()
    user = User(server, broker)
    src = "import autospada\n" + "".join(
        f"autospada.publish({{'i': {i}}})\n" for i in range(n_results)
    )
    a = user.assignment("x", [user.task("veh", user.payload(src))]).commit()
    client.run_until_idle()
    client.resync()
    client.run_until_idle()
    task_id = a.tasks[0].task_id
    results = [r.value for r in server.results(task_id)]
    assert results == [{"i": i} for i in range(n_results)]
    assert server.task(task_id).status == TaskStatus.FINISHED


@settings(max_examples=30, deadline=None)
@given(crash_point=st.integers(0, 3))
def test_restart_resumes_from_cached_state(crash_point):
    """The §5.1 histogram argument: cached state makes the counter resume
    monotonically across crashes instead of restarting from zero."""
    store, broker, (server,) = make_platform()
    disk = LocalDisk()
    client = EdgeClient("veh", server, broker, disk=disk)
    client.bootstrap()
    client.run_until_idle()
    user = User(server, broker)
    src = (
        "import autospada\n"
        "s = autospada.load_state()\n"
        "n = 0 if s is None else s['n']\n"
        "autospada.cache_state({'n': n + 1})\n"
        "autospada.publish({'n': n + 1})\n"
    )
    a = user.assignment("h", [user.task("veh", user.payload(src))]).commit()
    for i in range(crash_point):
        client.poll()
        client.step()
    client.shutdown()
    client = EdgeClient("veh", server, broker, disk=disk)
    client.bootstrap()
    client.run_until_idle()
    client.resync()
    client.run_until_idle()
    task_id = a.tasks[0].task_id
    task = server.task(task_id)
    assert task.status == TaskStatus.FINISHED
    ns = [r.value["n"] for r in server.results(task_id)]
    assert ns == sorted(set(ns))  # monotone, no duplicates
    # state cache is removed on completion (paper §5.1)
    assert task_id not in disk.task_state
