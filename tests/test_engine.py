"""Unified fleet event engine: one time-ordered heap (churn toggles,
service refills, deadline timers) must reproduce the dense per-tick poll
oracle bit-for-bit — aggregates, broker counters, pump counts — across a
seeded grid of faults × churn × stragglers, plus engine-unit contracts
(phase ordering, cancel/fired, on_status routing, O(1) counts)."""
import numpy as np
import pytest

from repro.core import TaskCounts
from repro.core.broker import Broker
from repro.fleet import (
    PHASE_CHURN,
    PHASE_SERVICE,
    PHASE_TIMER,
    Backends,
    EngineService,
    EventEngine,
    FedConfig,
    FleetServiceScheduler,
    FleetSimulator,
    SimConfig,
)
from repro.fleet.analytics import AnalyticsConfig
from repro.fleet.simulator import EngineBackend

ENGINE = dict(engine="event", service="scheduler", churn="event")
DENSE = dict(engine="dense", service="dense", churn="dense")


def _fingerprint(sim, driver):
    """Everything the parity contract pins down: aggregate, broker
    counters (same message-id sequence => same seeded fault schedule),
    per-round participation/cancels/pump counts, consumed ticks."""
    return (
        driver.w.copy(),
        (sim.broker.published, sim.broker.delivered, sim.broker.dropped),
        [r["participants"] for r in driver.history],
        [r["canceled"] for r in driver.history],
        [r["pumps"] for r in driver.history],
        sim.t,
    )


def _run(backends: dict, **overrides):
    cfg = dict(n_clients=48, seed=17)
    cfg.update(overrides)
    sim = FleetSimulator(SimConfig(backends=Backends(**backends), **cfg))
    driver = sim.run_federated(
        FedConfig(
            local_steps=2, local_lr=0.2, deadline_fraction=0.7,
            deadline_pumps=48,
        ),
        dim=16,
        rounds=3,
        n_samples=8,
    )
    return _fingerprint(sim, driver)


def _assert_equal(a, b):
    assert np.array_equal(a[0], b[0])
    assert a[1:] == b[1:]


# --------------------------------------------------------------------- #
# the tentpole contract: engine == dense poll oracle, bit for bit        #
# --------------------------------------------------------------------- #
GRID = {
    "clean": {},
    "faults": dict(p_drop=0.15, p_duplicate=0.05, max_delay=2),
    "churn": dict(p_leave=0.05, p_return=0.3),
    "stragglers": dict(straggler_fraction=0.25, straggler_period=8),
    "everything": dict(
        p_drop=0.15, p_duplicate=0.05, max_delay=2, p_leave=0.02,
        p_return=0.3, straggler_fraction=0.25, straggler_period=8,
    ),
}


@pytest.mark.parametrize("scenario", sorted(GRID))
def test_engine_matches_dense_oracle_bit_for_bit(scenario):
    """Same SimConfig through the unified heap and the fully dense tick
    (dense churn scan, dense poll service, statuses() round closes) must
    yield identical aggregates AND identical broker counters AND
    identical per-round pump counts — the strongest available witness
    that the event interleaving is reproduced exactly."""
    knobs = GRID[scenario]
    _assert_equal(_run(ENGINE, **knobs), _run(DENSE, **knobs))


@pytest.mark.parametrize("seed", [0, 7, 23])
def test_engine_parity_across_seeds(seed):
    knobs = dict(
        GRID["everything"], seed=seed, n_clients=32, resync_period=8
    )
    _assert_equal(_run(ENGINE, **knobs), _run(DENSE, **knobs))


def test_engine_composes_with_dense_suboracles():
    """Mixed backends — the unified heap driving the dense service and
    the dense churn scan — still match both the full-dense and the
    full-engine runs: every backend pair is interchangeable."""
    knobs = GRID["everything"]
    mixed = _run(dict(engine="event", service="dense", churn="dense"), **knobs)
    _assert_equal(mixed, _run(DENSE, **knobs))
    _assert_equal(mixed, _run(ENGINE, **knobs))


def test_engine_is_default_and_deterministic():
    sim = FleetSimulator(SimConfig(n_clients=8, seed=0))
    assert sim.engine is not None
    assert sim.cfg.engine is EngineBackend.EVENT
    assert isinstance(sim.service, EngineService)
    assert isinstance(sim.service, FleetServiceScheduler)  # drop-in
    a = _run(ENGINE, **GRID["everything"])
    b = _run(ENGINE, **GRID["everything"])
    _assert_equal(a, b)


def test_analytics_windows_close_on_status_events():
    """The analytics driver shares pump_until_deadline: engine-driven
    windows must match the dense oracle's sketches exactly."""

    def run(backends):
        sim = FleetSimulator(
            SimConfig(
                n_clients=24, seed=5, scenario="mixed", p_drop=0.1,
                max_delay=1, straggler_fraction=0.25, straggler_period=8,
                backends=Backends(**backends),
            )
        )
        drv = sim.run_analytics(
            AnalyticsConfig(deadline_fraction=0.7, deadline_pumps=32),
            windows=3,
            warmup_ticks=8,
        )
        stats = [
            (r.participants, r.canceled, r.pumps, r.count, r.mean, r.var)
            for r in drv.history
        ]
        hists = [r.hist.tolist() for r in drv.history]
        counters = (
            sim.broker.published, sim.broker.delivered, sim.broker.dropped
        )
        return stats, hists, counters, sim.t

    assert run(ENGINE) == run(DENSE)


# --------------------------------------------------------------------- #
# EventEngine unit contracts                                             #
# --------------------------------------------------------------------- #
def test_drain_orders_by_tick_phase_key_then_schedule_order():
    eng = EventEngine()
    log = []
    eng.schedule(2, lambda: log.append("t2-timer"), phase=PHASE_TIMER)
    eng.schedule(1, lambda: log.append("svc-9"), phase=PHASE_SERVICE, key=9)
    eng.schedule(1, lambda: log.append("churn-5"), phase=PHASE_CHURN, key=5)
    eng.schedule(1, lambda: log.append("svc-2a"), phase=PHASE_SERVICE, key=2)
    eng.schedule(1, lambda: log.append("svc-2b"), phase=PHASE_SERVICE, key=2)
    eng.schedule(1, lambda: log.append("churn-3"), phase=PHASE_CHURN, key=3)
    assert eng.drain(1) == 5
    # churn before service; ascending key; FIFO on full ties
    assert log == ["churn-3", "churn-5", "svc-2a", "svc-2b", "svc-9"]
    assert eng.drain(2) == 1
    assert log[-1] == "t2-timer"
    assert len(eng) == 0


def test_same_tick_schedules_fire_within_the_drain():
    """A churn-phase callback scheduling a service event at the same tick
    (a power-on queueing a refill) must see it fire in this drain."""
    eng = EventEngine()
    log = []
    eng.schedule(
        3,
        lambda: (
            log.append("churn"),
            eng.schedule(3, lambda: log.append("svc"), phase=PHASE_SERVICE),
        ),
        phase=PHASE_CHURN,
    )
    eng.drain(3)
    assert log == ["churn", "svc"]
    assert eng.now == 3 and not eng.draining


def test_entry_cancel_and_fired_flags():
    eng = EventEngine()
    hit = []
    keep = eng.schedule(1, lambda: hit.append("keep"))
    drop = eng.schedule(1, lambda: hit.append("drop"))
    drop.cancel()
    assert eng.drain(1) == 1
    assert hit == ["keep"]
    assert keep.fired and not drop.fired
    late = eng.schedule(2)  # deadline-style: no callback, observed via fired
    eng.drain(5)  # past-due entries fire on the next drain
    assert late.fired


def test_on_status_dispatches_reliably_and_wake_reaches_clients():
    broker = Broker()
    eng = EventEngine(broker)
    seen = []
    eng.on_status("assignments/a1/status", lambda m: seen.append(m.value))
    broker.publish("assignments/a1/status", {"task_id": "t", "status": "FINISHED"}, qos=1)
    broker.publish("assignments/other/status", {"x": 1}, qos=1)
    assert seen == [{"task_id": "t", "status": "FINISHED"}]

    woken = []
    eng.bind_wake("veh-1", lambda: woken.append(1))
    assert eng.wake("veh-1") and woken == [1]
    eng.unbind_wake("veh-1")
    assert not eng.wake("veh-1")
    with pytest.raises(RuntimeError):
        EventEngine().on_status("t", lambda m: None)


def test_engine_wake_makes_a_fleet_client_runnable():
    sim = FleetSimulator(SimConfig(n_clients=8, seed=2, resync_period=1024))
    sim.tick()
    assert sim.service.last_serviced <= 1
    assert sim.engine.wake("veh-003")  # no-op work-wise (idle), but bound
    for cid in sim.pool.vehicles:
        assert sim.engine.wake(cid)


# --------------------------------------------------------------------- #
# O(1) counts: status events, idempotence, cancels                       #
# --------------------------------------------------------------------- #
def test_counts_track_statuses_exactly_under_duplicated_streams():
    """p_duplicate=1.0 redelivers every QoS-1 message: the event-folded
    counters must stay exact (idempotent per task) and equal the dense
    statuses() scan at every pump."""
    sim = FleetSimulator(
        SimConfig(
            n_clients=12, seed=3, p_duplicate=1.0,
            straggler_fraction=0.25, straggler_period=64,
        )
    )
    payload = sim.user.payload("import autospada\nautospada.publish({'ok': 1})\n")
    assign = sim.user.assignment(
        "dup-storm", [sim.user.task(c, payload) for c in sim.user.online_clients()]
    ).commit()
    for _ in range(12):
        sim.tick()
        c = assign.counts()
        s = list(assign.statuses().values())
        assert c == TaskCounts(
            finished=s.count("FINISHED"),
            error=s.count("ERROR"),
            canceled=s.count("CANCELED"),
            active=s.count("ACTIVE"),
        )
    n_canceled = assign.cancel()  # gated stragglers still active
    c = assign.counts()
    assert n_canceled > 0 and c.canceled == n_canceled and c.active == 0
    assert c.terminal == 12


def test_counts_is_o1_not_a_rescan(monkeypatch):
    """counts() must never fall back to per-task server reads."""
    sim = FleetSimulator(SimConfig(n_clients=6, seed=1))
    payload = sim.user.payload("import autospada\nautospada.publish({'ok': 1})\n")
    assign = sim.user.assignment(
        "no-scan", [sim.user.task(c, payload) for c in sim.user.online_clients()]
    ).commit()
    monkeypatch.setattr(
        sim.user.server, "task",
        lambda *a, **k: pytest.fail("counts() re-scanned the server"),
    )
    for _ in range(8):
        sim.tick()
    assert assign.counts() == TaskCounts(finished=6, active=0)
    assert assign.results()  # results stream unaffected


def test_round_pumps_match_oracle_when_deadline_expires():
    """A quorum that can never be met (every client a straggler on a huge
    period) must burn exactly the pump budget — the engine's deadline
    timer and the oracle's loop bound agree."""
    knobs = dict(
        n_clients=8, seed=4, straggler_fraction=1.0, straggler_period=64
    )
    a = _run(ENGINE, **knobs)
    b = _run(DENSE, **knobs)
    _assert_equal(a, b)
    assert a[4][0] == 48  # round 1 burns the whole deadline_pumps budget
    assert all(p <= 48 for p in a[4])


# --------------------------------------------------------------------- #
# engine-native service: refill events, not masks                        #
# --------------------------------------------------------------------- #
def test_idle_fleet_services_only_the_resync_phase_class():
    sim = FleetSimulator(SimConfig(n_clients=32, seed=1, resync_period=8))
    assert isinstance(sim.service, EngineService)
    for _ in range(16):
        sim.tick()
        assert sim.service.last_serviced == 4


def test_power_cycles_go_stale_not_wrong():
    """Refill events booked before a power-off must not service the old
    client object; the rebooted client gets fresh events."""
    sim = FleetSimulator(SimConfig(n_clients=6, seed=4, resync_period=4))
    cid = "veh-002"
    sim.pool.power_off(cid)
    for _ in range(8):
        sim.tick()
    sim.pool.power_on(cid)
    sim.pool.vehicles[cid].client.run_until_idle()
    payload = sim.user.payload("import autospada\nautospada.publish({'v': 7})\n")
    assign = sim.user.assignment(
        "after-reboot", [sim.user.task(cid, payload)]
    ).commit()
    for _ in range(8):
        sim.tick()
    assert set(assign.statuses().values()) == {"FINISHED"}
    assert assign.counts().finished == 1


def test_new_vehicles_join_the_engine_schedule():
    sim = FleetSimulator(SimConfig(n_clients=8, seed=1))
    driver = sim.run_federated(
        FedConfig(local_steps=3, local_lr=0.2, deadline_fraction=1.0),
        dim=16, rounds=1, n_samples=16,
    )
    for _ in range(4):
        cid = sim.pool.add_vehicle()
        sim.pool.vehicles[cid].client.run_until_idle()
    rec = driver.run_round(1, pump=sim.tick)
    assert rec["participants"] == 12


# --------------------------------------------------------------------- #
# property test: random event interleavings (graceful skip)              #
# --------------------------------------------------------------------- #
def _property_parity(seed, n, p_drop, p_dup, delay, p_leave, p_return,
                     frac, resync):
    knobs = dict(
        n_clients=n, seed=seed, p_drop=p_drop, p_duplicate=p_dup,
        max_delay=delay, p_leave=p_leave, p_return=p_return,
        straggler_fraction=frac, resync_period=resync,
    )

    def run(backends):
        sim = FleetSimulator(SimConfig(backends=Backends(**backends), **knobs))
        drv = sim.run_federated(
            FedConfig(
                local_steps=1, local_lr=0.2, deadline_fraction=0.7,
                deadline_pumps=24,
            ),
            dim=8, rounds=2, n_samples=4,
        )
        return _fingerprint(sim, drv)

    _assert_equal(run(ENGINE), run(DENSE))


try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
except ImportError:  # graceful skip — hypothesis is optional
    @pytest.mark.skip(reason="property tests need hypothesis")
    def test_random_interleavings_stay_bit_for_bit():
        pass
else:
    @settings(
        max_examples=10,
        deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(
        seed=st.integers(0, 2**16),
        n=st.integers(4, 24),
        p_drop=st.floats(0.0, 0.3),
        p_dup=st.floats(0.0, 0.2),
        delay=st.integers(0, 3),
        p_leave=st.floats(0.0, 0.1),
        p_return=st.floats(0.0, 0.5),
        frac=st.floats(0.0, 0.5),
        resync=st.integers(1, 8),
    )
    def test_random_interleavings_stay_bit_for_bit(
        seed, n, p_drop, p_dup, delay, p_leave, p_return, frac, resync
    ):
        _property_parity(
            seed, n, p_drop, p_dup, delay, p_leave, p_return, frac, resync
        )
