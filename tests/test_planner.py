"""Sharding planner rules on the production mesh shape (AbstractMesh:
no devices needed — specs are pure metadata)."""
import jax
import jax.numpy as jnp
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.models.model import cache_spec, init_params
from repro.sharding import planner

MESH = AbstractMesh((("data", 16), ("model", 16)))
MESH_MP = AbstractMesh((("pod", 2), ("data", 16), ("model", 16)))


def shapes_of(arch):
    cfg = get_config(arch)
    return cfg, jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
    )


def spec_map(tree):
    return planner.describe(tree)


def test_divisible_heads_shard_on_model():
    cfg, shapes = shapes_of("granite-8b")
    sh = planner.param_shardings(cfg, shapes, MESH, fsdp=False)
    m = spec_map(sh)
    assert "'model'" in m["groups/0/0/mixer/wq"].replace('"', "'")
    # kv heads = 8 < 16 -> replicated
    assert "model" not in m["groups/0/0/mixer/wk"]
    assert "data" not in m["groups/0/0/mixer/wk"]


def test_gemma3_four_heads_fall_back_to_replicated():
    cfg, shapes = shapes_of("gemma3-1b")
    sh = planner.param_shardings(cfg, shapes, MESH, fsdp=False)
    m = spec_map(sh)
    assert "model" not in m["groups/0/0/mixer/wq"]
    # but the 262k vocab shards
    assert "model" in m["embed"]
    # and the MLP shards
    assert "model" in m["groups/0/0/ffn/w_gate"]


def test_mixtral_8_experts_use_tp_within_expert():
    cfg, shapes = shapes_of("mixtral-8x22b")
    sh = planner.param_shardings(cfg, shapes, MESH, fsdp=False)
    m = spec_map(sh)
    # 8 % 16 != 0: expert dim unsharded, f sharded on model
    assert m["groups/0/0/ffn/w_gate"] == "PartitionSpec(None, None, None, 'model')"


def test_jamba_16_experts_use_expert_parallelism():
    cfg, shapes = shapes_of("jamba-1.5-large-398b")
    sh = planner.param_shardings(cfg, shapes, MESH, fsdp=False)
    m = spec_map(sh)
    assert m["groups/0/1/ffn/w_gate"].startswith(
        "PartitionSpec(None, 'model'"
    )


def test_fsdp_adds_data_axis_for_big_models():
    cfg, shapes = shapes_of("mixtral-8x22b")
    sh = planner.param_shardings(cfg, shapes, MESH)  # auto => fsdp on (141B)
    m = spec_map(sh)
    assert "data" in m["groups/0/0/ffn/w_gate"]


def test_every_spec_divides_its_dimension():
    """No spec may assign an axis that does not divide the dim — for every
    arch, every param, every mesh."""
    for arch in ("jamba-1.5-large-398b", "gemma3-1b", "mixtral-8x22b",
                 "granite-moe-1b-a400m", "xlstm-1.3b", "musicgen-large",
                 "h2o-danube-3-4b"):
        cfg, shapes = shapes_of(arch)
        for mesh in (MESH, MESH_MP):
            for serve in (False, True):
                sh = planner.param_shardings(
                    cfg, shapes, mesh, serve=serve
                )
                _assert_divisible(shapes, sh, mesh, arch)


def _assert_divisible(shapes, shardings, mesh, tag):
    for leaf, s in zip(
        jax.tree.leaves(shapes),
        jax.tree.leaves(shardings, is_leaf=lambda x: hasattr(x, "spec")),
    ):
        for dim, axes in enumerate(s.spec):
            if axes is None:
                continue
            n = 1
            for a in (axes if isinstance(axes, tuple) else (axes,)):
                n *= mesh.shape[a]
            assert leaf.shape[dim] % n == 0, (tag, leaf.shape, s.spec)


def test_cache_specs_divide_and_cover():
    for arch, shape_seq, B in (
        ("granite-8b", 32768, 128),
        ("jamba-1.5-large-398b", 524288, 1),
        ("xlstm-1.3b", 524288, 1),
        ("mixtral-8x22b", 32768, 128),
    ):
        cfg = get_config(arch)
        cs = cache_spec(cfg, B, shape_seq)
        sh = planner.cache_shardings(cfg, cs, MESH)
        _assert_divisible(cs, sh, MESH, arch)


def test_batch_sharding_uses_pod_and_data():
    b = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    sh = planner.batch_shardings(b, MESH_MP)
    assert sh["tokens"].spec == P(("pod", "data"), None)
    b1 = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
    sh1 = planner.batch_shardings(b1, MESH_MP)
    assert sh1["tokens"].spec == P(None, None)
