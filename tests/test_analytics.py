"""Streaming-analytics workload: batched sketch merges vs the sequential
reference, the end-to-end AnalyticsDriver, sample-count-weighted FedAvg,
and the payload API satellites (signal windows, virtual-clock sleep)."""
import time

import numpy as np
import pytest

from repro.core import PayloadContext, User, dummy_context, make_platform
from repro.core.signals import ScriptedSignalBroker, SignalHandler, constant
from repro.fleet import (
    AnalyticsConfig,
    FedConfig,
    FederatedDriver,
    FleetPool,
    FleetSimulator,
    SimConfig,
    aggregate_reference,
    merge_moments_reference,
)
from repro.kernels.ops import merge_histograms, merge_moments


# --------------------------------------------------------------------- #
# batched merges vs per-client reference                                 #
# --------------------------------------------------------------------- #
def test_merge_moments_matches_sequential_reference():
    rng = np.random.default_rng(0)
    sketches = []
    for _ in range(64):
        x = rng.normal(loc=rng.uniform(-3, 3), scale=rng.uniform(0.1, 2), size=rng.integers(5, 200))
        sketches.append((float(len(x)), float(np.mean(x)), float(np.var(x) * len(x))))
    counts, means, m2s = map(np.asarray, zip(*sketches))
    c, mean, m2 = merge_moments(counts, means, m2s)
    cr, meanr, m2r = merge_moments_reference(sketches)
    assert c == cr
    assert mean == pytest.approx(meanr, rel=1e-5)
    assert m2 == pytest.approx(m2r, rel=1e-4)
    # and both equal the pooled ground truth computed from scratch
    pooled_mean = float(np.sum(counts * means) / np.sum(counts))
    assert mean == pytest.approx(pooled_mean, rel=1e-5)


def test_merge_moments_handles_empty_sketches():
    c, mean, m2 = merge_moments(
        np.array([0.0, 5.0]), np.array([0.0, 2.0]), np.array([0.0, 10.0])
    )
    cr, meanr, m2r = merge_moments_reference([(0, 0.0, 0.0), (5, 2.0, 10.0)])
    assert (c, mean, m2) == (cr, meanr, m2r) == (5.0, 2.0, 10.0)


def test_merge_histograms_matches_numpy_sum():
    rng = np.random.default_rng(1)
    hists = rng.integers(0, 50, size=(32, 16))
    assert np.array_equal(merge_histograms(hists), hists.sum(axis=0))


# --------------------------------------------------------------------- #
# the analytics workload end-to-end                                      #
# --------------------------------------------------------------------- #
def test_analytics_driver_end_to_end_matches_reference_merge():
    sim = FleetSimulator(SimConfig(n_clients=8, seed=4, scenario="mixed"))
    cfg = AnalyticsConfig(window=16, bins=8, deadline_fraction=1.0)
    driver = sim.run_analytics(cfg, windows=2, warmup_ticks=6)
    assert len(driver.history) == 2
    for rec in driver.history:
        assert rec.participants == 8
        assert rec.count > 0
        assert int(rec.hist.sum()) == rec.count  # support clips every sample
    # the batched jit merge equals the sequential per-client reference
    sk = driver.last_sketches
    assert len(sk) == 8
    cr, meanr, m2r = merge_moments_reference(
        [(s["count"], s["mean"], s["m2"]) for s in sk]
    )
    last = driver.history[-1]
    assert last.count == int(cr)
    assert last.mean == pytest.approx(meanr, rel=1e-5)
    assert last.var == pytest.approx(m2r / cr, rel=1e-4)
    assert np.array_equal(
        last.hist, np.sum([s["hist"] for s in sk], axis=0)
    )


def test_analytics_is_deterministic_in_the_seed():
    def run():
        sim = FleetSimulator(SimConfig(n_clients=6, seed=11, scenario="urban"))
        d = sim.run_analytics(
            AnalyticsConfig(window=12, bins=6, deadline_fraction=1.0),
            windows=2,
            warmup_ticks=4,
        )
        return d.history[-1]

    a, b = run(), run()
    assert (a.count, a.mean, a.var) == (b.count, b.mean, b.var)
    assert np.array_equal(a.hist, b.hist)


# --------------------------------------------------------------------- #
# weighted FedAvg (satellite)                                            #
# --------------------------------------------------------------------- #
def test_fedavg_weights_by_sample_count_as_reference_predicts():
    store, broker, (server,) = make_platform()
    pool = FleetPool(
        store, broker, server, n_vehicles=3,
        signal_fn=lambda i: {"Vehicle.RoadGrade": constant(0.05 * i)},
    )
    user = User(server, broker)
    counts = [8, 32, 120]
    drv = FederatedDriver(
        user,
        FedConfig(local_steps=2, local_lr=0.2, deadline_fraction=1.0),
        dim=6,
        w_true=np.linspace(-1, 1, 6).astype(np.float32),
        n_samples_fn=lambda i: counts[i],
    )
    rec = drv.run_round(0, pump=pool.pump)
    assert rec["participants"] == 3
    assert sorted(rec["weights"]) == sorted(float(c) for c in counts)
    # the driver's update equals the reference weighted loop on the raw
    # uploads (w started at zero, server_lr = 1)
    msgs = drv.last_msgs
    w = np.asarray([m["n_samples"] for m in msgs], np.float32)
    expected = aggregate_reference(msgs, w)
    assert np.allclose(drv.w, expected, atol=1e-6)
    # and unequal weights genuinely change the aggregate
    uniform = aggregate_reference(msgs)
    assert not np.allclose(expected, uniform, atol=1e-6)


# --------------------------------------------------------------------- #
# payload API satellites                                                 #
# --------------------------------------------------------------------- #
def test_get_signal_window_through_handler_and_dummy():
    broker = ScriptedSignalBroker({"s": iter([1.0, 2.0, 3.0, 4.0])})
    h = SignalHandler(broker)
    ctx = PayloadContext(
        get_signal=h.get,
        get_signal_window=h.window,
        publish=lambda v: None,
    )
    assert ctx.get_signal("s") == 1.0
    # push brokers record history lazily: the first window() call seeds it
    # with the current latest value and recording continues from there
    assert ctx.get_signal_window("s", 4) == [1.0]
    broker.tick()
    broker.tick()
    assert ctx.get_signal_window("s", 2) == [2.0, 3.0]
    assert ctx.get_signal_window("s", 99) == [1.0, 2.0, 3.0]
    assert len(dummy_context(seed=1).get_signal_window("x", 5)) == 5


def test_get_signal_window_falls_back_to_latest_value():
    ctx = PayloadContext(get_signal=lambda n: 7.0, publish=lambda v: None)
    assert ctx.get_signal_window("anything", 10) == [7.0]


def test_sleep_with_virtual_clock_does_not_burn_wall_time():
    """A simulated 30 s sleep must finish in (nearly) zero wall time when
    the injected clock is virtual (satellite fix: the old implementation
    napped 2 ms of real time per check even in simulation)."""
    sim_time = {"t": 0.0}

    def clock() -> float:
        sim_time["t"] += 0.05  # the world advances whenever anyone looks
        return sim_time["t"]

    ctx = PayloadContext(get_signal=lambda n: None, publish=lambda v: None, clock=clock)
    start = time.perf_counter()
    ctx.sleep(30.0)  # 600 virtual-clock checks
    assert time.perf_counter() - start < 0.5
    assert sim_time["t"] >= 30.0


def test_sleep_with_wall_clock_still_sleeps():
    ctx = PayloadContext(get_signal=lambda n: None, publish=lambda v: None)
    start = time.perf_counter()
    ctx.sleep(0.03)
    assert time.perf_counter() - start >= 0.02
    # wrapped wall clocks can opt out of virtual-clock detection
    wrapped = PayloadContext(
        get_signal=lambda n: None,
        publish=lambda v: None,
        clock=lambda: time.monotonic(),
        virtual_clock=False,
    )
    assert not wrapped._virtual_clock


def test_analytics_unknown_signal_reports_nan_not_zero():
    sim = FleetSimulator(SimConfig(n_clients=4, seed=2, scenario="mixed"))
    driver = sim.run_analytics(
        AnalyticsConfig(signal="Vehicle.DoesNotExist", deadline_fraction=1.0),
        windows=1,
        warmup_ticks=2,
    )
    rec = driver.history[0]
    assert rec.participants == 4 and rec.count == 0
    assert np.isnan(rec.mean) and np.isnan(rec.var)
