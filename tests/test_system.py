"""End-to-end platform behaviour (paper §5 workflow, §4.1.1 lifecycle)."""
from repro.core import (
    EdgeClient,
    ResourceLimits,
    ScriptedSignalBroker,
    TaskStatus,
    User,
    make_platform,
)
from repro.core.signals import constant

MEAN_PAYLOAD = """
import autospada
p = autospada.get_parameters()
total = 0.0
for i in range(p["n"]):
    total += autospada.get_signal(p["signal_name"])
autospada.publish({"mean": total / p["n"]})
"""


def make_world(n_vehicles=2, n_servers=1, signal_value=17.0):
    store, broker, servers = make_platform(n_servers=n_servers)
    clients = []
    for i in range(n_vehicles):
        sig = ScriptedSignalBroker({"Vehicle.Speed": constant(signal_value)})
        c = EdgeClient(f"veh-{i}", servers[i % len(servers)], broker, signal_broker=sig)
        c.bootstrap()
        c.run_until_idle()
        clients.append((c, sig))
    user = User(servers[0], broker)

    def pump():
        for c, sig in clients:
            sig.tick()
            c.run_until_idle()

    return store, broker, servers, clients, user, pump


def test_listing1_mean_speed_workflow():
    """The paper's §5.2.1 workflow end to end."""
    store, broker, servers, clients, user, pump = make_world()
    payload = user.payload(MEAN_PAYLOAD, name="mean-speed")
    params = user.parameter({"n": 5, "signal_name": "Vehicle.Speed"})
    tasks = [user.task(c, payload, params) for c in user.online_clients()]
    assign = user.assignment("Mean speed", tasks)
    results = assign.commit().await_results(pump)
    assert len(results) == 2
    for values in results.values():
        assert values == [{"mean": 17.0}]
    assert all(s == "FINISHED" for s in assign.statuses().values())


def test_error_status_uploads_container_log():
    store, broker, servers, clients, user, pump = make_world(n_vehicles=1)
    bad = user.payload("import autospada\nraise ValueError('boom')\n")
    assign = user.assignment("bad", [user.task("veh-0", bad)]).commit()
    pump()
    task_id = assign.tasks[0].task_id
    task = servers[0].task(task_id)
    assert task.status == TaskStatus.ERROR
    assert "boom" in task.error_log


def test_cancel_semantics():
    """Only ACTIVE tasks can be canceled; cancel stops the container."""
    store, broker, servers, clients, user, pump = make_world(n_vehicles=1)
    done = user.payload("import autospada\nautospada.publish({'x': 1})\n")
    assign = user.assignment("d", [user.task("veh-0", done)]).commit()
    pump()
    assert assign.cancel() == 0  # already FINISHED -> not cancelable
    # an assignment canceled before any client syncs never runs
    a2 = user.assignment("never", [user.task("veh-0", done)])
    a2.commit()
    assert a2.cancel() == 1
    pump()
    assert servers[0].task(a2.tasks[0].task_id).status == TaskStatus.CANCELED
    assert servers[0].results(a2.tasks[0].task_id) == []


def test_stateless_servers_interchangeable():
    """Any server instance serves any request (paper §3.2): round-robin
    every call across three instances."""
    store, broker, servers, clients, user, pump = make_world(n_servers=3)

    class RoundRobin:
        def __init__(self, servers):
            self._servers = servers
            self._i = 0

        def __getattr__(self, name):
            s = self._servers[self._i % len(self._servers)]
            self._i += 1
            return getattr(s, name)

    rr_user = User(RoundRobin(servers), broker)
    payload = rr_user.payload(MEAN_PAYLOAD)
    params = rr_user.parameter({"n": 2, "signal_name": "Vehicle.Speed"})
    tasks = [rr_user.task(c, payload, params) for c in rr_user.online_clients()]
    results = rr_user.assignment("rr", tasks).commit().await_results(pump)
    assert all(v == [{"mean": 17.0}] for v in results.values())


def test_result_streaming():
    store, broker, servers, clients, user, pump = make_world(n_vehicles=1)
    multi = user.payload(
        "import autospada\nfor i in range(3):\n    autospada.publish({'i': i})\n"
    )
    assign = user.assignment("s", [user.task("veh-0", multi)]).commit()
    assign.await_results(pump)
    streamed = list(assign.stream_results())
    assert [m["value"]["i"] for m in streamed] == [0, 1, 2]


def test_resource_quota_turns_into_error():
    store, broker, servers, _, user, pump = make_world(n_vehicles=0)
    sig = ScriptedSignalBroker({})
    c = EdgeClient(
        "veh-q", servers[0], broker, signal_broker=sig,
        limits=ResourceLimits(max_results=2),
    )
    c.bootstrap()
    c.run_until_idle()
    greedy = user.payload(
        "import autospada\nfor i in range(10):\n    autospada.publish({'i': i})\n"
    )
    assign = user.assignment("q", [user.task("veh-q", greedy)]).commit()
    c.run_until_idle()
    task = servers[0].task(assign.tasks[0].task_id)
    assert task.status == TaskStatus.ERROR
    assert "QuotaExceeded" in task.error_log


def test_payload_cache_hits_for_immutable_docs():
    """Re-running the same payload must not re-download it (paper §3.4.1)."""
    store, broker, servers, clients, user, pump = make_world(n_vehicles=1)
    c, _ = clients[0]
    payload = user.payload("import autospada\nautospada.publish({'ok': 1})\n")
    user.assignment("a1", [user.task("veh-0", payload)]).commit()
    pump()
    fetches_before = len(c.disk.payload_cache)
    a2 = user.assignment("a2", [user.task("veh-0", payload)]).commit()
    pump()
    assert len(c.disk.payload_cache) == fetches_before  # cache hit
    assert list(a2.results().values())[0] == [{"ok": 1}]


def test_sandbox_blocks_dangerous_imports():
    from repro.core import dummy_context, run_inline

    exit = run_inline("import os\n", dummy_context())
    assert exit.exit_code == 1
    assert "ImportError" in exit.log


def test_dummy_mode_runs_payload_standalone(capsys):
    """Paper §5.1.1: payloads run as ordinary scripts with the dummy lib."""
    from repro.core import dummy_context, run_inline

    ctx = dummy_context(seed=0, parameters={"n": 3, "signal_name": "x"})
    exit = run_inline(MEAN_PAYLOAD, ctx)
    assert exit.exit_code == 0, exit.log
    assert ctx.published_count == 1
