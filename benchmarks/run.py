"""Benchmark harness — one section per paper table/figure + the roofline
summary from the dry-run artifacts. Prints ``name,us_per_call,derived``
CSV rows. Run: PYTHONPATH=src python -m benchmarks.run [--fast]

``--json PATH`` additionally writes the machine-readable result —
section, metric, best-of-k seconds, and the guarded speedups — which CI
uploads as ``BENCH_fast.json`` so the bench trajectory is queryable, not
just CSV text in a log. When a committed baseline exists
(``benchmarks/BENCH_baseline.json``), ``trend/*`` rows compare each
guarded speedup against it; trend lines are informational (machines
differ) — the hard floor stays in ``fleet_scale.check_guard``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

#: committed reference point for the trend lines
DEFAULT_BASELINE = os.path.join(
    os.path.dirname(__file__), "BENCH_baseline.json"
)


def _section_of(name: str) -> str:
    return name.split("/", 1)[0] if "/" in name else name


def write_json(
    path: str,
    rows: list[tuple[str, float, str]],
    speedups: dict[str, dict[int, float]],
    *,
    fast: bool,
    guard_error: str | None,
) -> None:
    doc = {
        "schema": 1,
        "mode": "fast" if fast else "full",
        "guard_error": guard_error,
        "rows": [
            {
                "section": _section_of(name),
                "metric": name,
                "best_of_k_seconds": us / 1e6,
                "derived": derived,
            }
            for name, us, derived in rows
        ],
        "speedups": {
            section: {str(k): v for k, v in per_n.items()}
            for section, per_n in speedups.items()
        },
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


def trend_rows(
    speedups: dict[str, dict[int, float]], baseline_path: str
) -> list[str]:
    """``trend/<section>_<N>`` CSV rows: current guarded speedup vs the
    committed baseline's. Missing/unreadable baseline degrades to a note
    (first run, or a section added since the baseline was captured)."""
    try:
        with open(baseline_path) as f:
            base = json.load(f).get("speedups", {})
    except (OSError, ValueError) as e:
        return [f"trend/no_baseline,0,{baseline_path}: {e}"]
    out = []
    for section, per_n in sorted(speedups.items()):
        for n, cur in sorted(per_n.items()):
            ref = base.get(section, {}).get(str(n))
            if ref is None:
                out.append(
                    f"trend/{section}_{n},{cur:.2f},"
                    f"{cur:.2f}x speedup; not in baseline yet"
                )
            else:
                delta = (cur / ref - 1.0) * 100.0
                out.append(
                    f"trend/{section}_{n},{cur:.2f},"
                    f"{cur:.2f}x vs baseline {ref:.2f}x ({delta:+.0f}%)"
                )
    return out


def _kernel_rows(fast: bool) -> list[tuple[str, float, str]]:
    """CPU micro-timings of the attention paths (indicative only — TPU is
    the target; these catch gross regressions in the XLA-path algorithms)."""
    import jax
    import jax.numpy as jnp

    from repro.models.attention import (
        flash_attention,
        local_attention,
        reference_attention,
    )

    key = jax.random.PRNGKey(0)
    B, S, H, KV, D = 1, 1024, 8, 4, 64
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)

    def timeit(fn, *args, reps=3):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / reps * 1e6

    flash = jax.jit(lambda q, k, v: flash_attention(q, k, v, q_block=256, kv_block=256))
    loc = jax.jit(lambda q, k, v: local_attention(q, k, v, window=256, q_block=128))
    ref = jax.jit(lambda q, k, v: reference_attention(q, k, v))
    t_flash = timeit(flash, q, k, v)
    t_local = timeit(loc, q, k, v)
    t_ref = timeit(ref, q, k, v)
    return [
        ("kernel/flash_attention_xla_1k", t_flash, f"vs materializing ref {t_ref:.0f}us"),
        ("kernel/local_attention_w256_1k", t_local, f"{t_ref/t_local:.2f}x faster than dense ref"),
        ("kernel/reference_attention_1k", t_ref, "materializing oracle"),
    ]


def _throughput_rows(fast: bool) -> list[tuple[str, float, str]]:
    """Platform throughput: assignments/sec through commit->run->results."""
    from repro.core import EdgeClient, User, make_platform

    store, broker, (server,) = make_platform()
    client = EdgeClient("veh-0", server, broker)
    client.bootstrap(); client.run_until_idle()
    user = User(server, broker)
    payload = user.payload("import autospada\nautospada.publish({'ok': 1})\n")
    n = 50 if fast else 200
    t0 = time.perf_counter()
    assigns = [user.assignment(f"t{i}", [user.task("veh-0", payload)]).commit()
               for i in range(n)]
    client.run_until_idle()
    dt = time.perf_counter() - t0
    done = sum(
        1 for a in assigns
        if all(s == "FINISHED" for s in a.statuses().values())
    )
    assert done == n, (done, n)
    return [("platform/task_roundtrip", dt / n * 1e6, f"{n/dt:.0f} tasks/s end-to-end")]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer repetitions")
    ap.add_argument(
        "--json",
        metavar="PATH",
        default=None,
        help="also write machine-readable results (section, metric, "
        "best-of-k seconds, speedups) to PATH — the CI artifact",
    )
    ap.add_argument(
        "--baseline",
        metavar="PATH",
        default=DEFAULT_BASELINE,
        help="baseline JSON for the trend/* rows "
        "(default: the committed benchmarks/BENCH_baseline.json)",
    )
    args = ap.parse_args()
    fast = args.fast

    rows: list[tuple[str, float, str]] = []
    print("name,us_per_call,derived")

    def emit(new_rows):
        for name, us, derived in new_rows:
            print(f"{name},{us:.2f},{derived}")
            sys.stdout.flush()
        rows.extend(new_rows)

    from benchmarks import fleet_scale, serve_load, table2_latency, table3_memory

    emit(table2_latency.rows(n=20 if fast else 100))
    emit(table3_memory.rows())
    emit(_throughput_rows(fast))
    emit(_kernel_rows(fast))
    fleet_rows, speedups = fleet_scale.rows(fast)
    emit(fleet_rows)
    serve_rows, serve_speedups = serve_load.rows(fast)
    emit(serve_rows)
    speedups = {**speedups, "serve": serve_speedups}
    try:
        from benchmarks import roofline

        emit(roofline.rows())
    except Exception as e:  # dry-run artifacts absent
        print(f"roofline/skipped,0,run repro.launch.dryrun first ({e})")

    # perf-regression guard: a vectorized fleet path (batched aggregation,
    # columnar/sharded signal-plane step, the gateway's cached-fold read
    # path) losing to its per-client baseline fails the whole benchmark
    # run (and with it CI)
    err = fleet_scale.check_guard(speedups, fast=fast)
    if err is None:
        err = serve_load.check_guard(serve_speedups, fast=fast)
    if os.environ.get("BENCH_FORCE_GUARD_FAIL"):
        # CI plumbing self-test: prove a guard failure actually fails the
        # job (the bench-smoke step pipes through `tee`, which without
        # pipefail swallows this exit code — see .github/workflows/ci.yml)
        err = err or "forced failure (BENCH_FORCE_GUARD_FAIL is set)"
    for line in trend_rows(speedups, args.baseline):
        print(line)
    if args.json:
        write_json(args.json, rows, speedups, fast=fast, guard_error=err)
    if err:
        print(f"fleet/guard_failed,0,{err}")
        sys.exit(1)


if __name__ == "__main__":
    main()
