"""Paper Table 2 reproduction: task-lifecycle latencies, n=100.

Same four measurements, same protocol hop structure (commit -> notify ->
fetch -> payload-pull -> container start -> publish -> submit -> stream):

  t_start — task.commit() .. first result observed by the user
  t_delay — between two back-to-back results from the same task
  t_exit  — second result .. FINISHED status observed
  t_cycle — commit .. FINISHED for a do-nothing payload

The paper ran Raspberry-Pi-over-WiFi against GKE (seconds regime); we run
the faithful in-process platform (microseconds regime). The *ratios* are
the comparable quantity: t_delay << t_start (no container setup on the
result path) and t_exit < t_start, which Table 2 also shows.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import EdgeClient, TaskStatus, User, make_platform

TWO_RESULT_PAYLOAD = """
import autospada
autospada.publish({})
autospada.publish({})
"""

NOOP_PAYLOAD = """
import autospada
"""


def run(n: int = 100) -> dict[str, dict[str, float]]:
    store, broker, (server,) = make_platform()
    client = EdgeClient("veh-0", server, broker)
    client.bootstrap()
    client.run_until_idle()
    user = User(server, broker)

    t_start, t_delay, t_exit, t_cycle = [], [], [], []
    for i in range(n):
        # fresh payload each iteration (paper: caching would skew t_start)
        payload = user.payload(TWO_RESULT_PAYLOAD + f"# {i}\n")
        sub = user.broker.subscribe("assignments/*/results", qos=1)
        ssub = user.broker.subscribe("assignments/*/status", qos=1)
        t0 = time.perf_counter()
        assign = user.assignment(f"m{i}", [user.task("veh-0", payload)]).commit()
        first = second = fin = None
        while fin is None:
            client.run_until_idle()
            for m in sub.drain():
                if first is None:
                    first = time.perf_counter()
                elif second is None:
                    second = time.perf_counter()
            for m in ssub.drain():
                if m.value.get("status") == TaskStatus.FINISHED.value:
                    fin = time.perf_counter()
        t_start.append(first - t0)
        t_delay.append(second - first)
        t_exit.append(fin - second)
        user.broker.unsubscribe(sub)
        user.broker.unsubscribe(ssub)

        payload2 = user.payload(NOOP_PAYLOAD + f"# {i}\n")
        ssub = user.broker.subscribe("assignments/*/status", qos=1)
        t0 = time.perf_counter()
        a2 = user.assignment(f"c{i}", [user.task("veh-0", payload2)]).commit()
        fin = None
        while fin is None:
            client.run_until_idle()
            for m in ssub.drain():
                if m.value.get("status") == TaskStatus.FINISHED.value:
                    fin = time.perf_counter()
        t_cycle.append(fin - t0)
        user.broker.unsubscribe(ssub)

    def stats(xs):
        a = np.asarray(xs)
        return {
            "mean": float(a.mean()),
            "sd": float(a.std(ddof=1)),
            "p5": float(np.percentile(a, 5)),
            "p95": float(np.percentile(a, 95)),
        }

    return {
        "t_start": stats(t_start),
        "t_delay": stats(t_delay),
        "t_exit": stats(t_exit),
        "t_cycle": stats(t_cycle),
    }


def rows(n: int = 100) -> list[tuple[str, float, str]]:
    r = run(n)
    out = []
    for name, s in r.items():
        out.append(
            (
                f"table2/{name}",
                s["mean"] * 1e6,
                f"sd={s['sd']*1e6:.1f}us p5={s['p5']*1e6:.1f} p95={s['p95']*1e6:.1f} n={n}",
            )
        )
    # the paper's qualitative claims, checked numerically
    assert r["t_delay"]["mean"] < r["t_start"]["mean"], "t_delay must be smallest"
    return out
