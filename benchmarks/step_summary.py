"""Render the bench-smoke run as a GitHub Actions step summary.

Reads the machine-readable benchmark artifact (``BENCH_fast.json``,
written by ``benchmarks.run --json``) plus the committed baseline and
prints a markdown report — guard verdict, guarded-speedup trend table,
and the full row dump in a collapsed section. CI appends the output to
``$GITHUB_STEP_SUMMARY`` so the perf trajectory is readable from the
run page without downloading artifacts.

Degrades instead of failing: the summary step runs ``if: always()`` and
must never turn a green run red (or hide a red one) — a missing or
unreadable artifact becomes a note in the summary, exit code 0.

Run: ``PYTHONPATH=src python -m benchmarks.step_summary --json BENCH_fast.json``
"""
from __future__ import annotations

import argparse
import json

from benchmarks.run import DEFAULT_BASELINE


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def trend_table(cur: dict, base: dict | None) -> list[str]:
    """One row per guarded speedup: current vs the committed baseline."""
    lines = [
        "| section | N | speedup | baseline | delta |",
        "|---|---:|---:|---:|---:|",
    ]
    base_sp = (base or {}).get("speedups", {})
    for section, per_n in sorted(cur.get("speedups", {}).items()):
        for n, val in sorted(per_n.items(), key=lambda kv: int(kv[0])):
            ref = base_sp.get(section, {}).get(n)
            if ref is None:
                lines.append(
                    f"| {section} | {n} | {val:.2f}x | — | new |"
                )
            else:
                delta = (val / ref - 1.0) * 100.0
                lines.append(
                    f"| {section} | {n} | {val:.2f}x | {ref:.2f}x "
                    f"| {delta:+.0f}% |"
                )
    return lines


def row_dump(cur: dict) -> list[str]:
    rows = cur.get("rows", [])
    lines = [
        "<details>",
        f"<summary>All rows ({len(rows)})</summary>",
        "",
        "| metric | best-of-k | derived |",
        "|---|---:|---|",
    ]
    for r in rows:
        us = r["best_of_k_seconds"] * 1e6
        t = f"{us / 1e6:.2f} s" if us >= 1e6 else (
            f"{us / 1e3:.2f} ms" if us >= 1e3 else f"{us:.2f} us"
        )
        derived = str(r["derived"]).replace("|", "\\|")
        lines.append(f"| {r['metric']} | {t} | {derived} |")
    lines += ["", "</details>"]
    return lines


def render(json_path: str, baseline_path: str) -> str:
    cur = _load(json_path)
    if cur is None:
        return (
            "## Benchmark smoke\n\n"
            f"No benchmark artifact at `{json_path}` — the bench run "
            "failed before writing results (see the step log).\n"
        )
    base = _load(baseline_path)
    err = cur.get("guard_error")
    verdict = (
        f":x: **guard failed** — {err}" if err
        else ":white_check_mark: guards passed"
    )
    lines = [
        f"## Benchmark smoke ({cur.get('mode', '?')} mode)",
        "",
        verdict,
        "",
        "### Guarded speedups vs committed baseline",
        "",
    ]
    lines += trend_table(cur, base)
    if base is None:
        lines += ["", f"_(no baseline at `{baseline_path}`)_"]
    lines += [""] + row_dump(cur) + [""]
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="BENCH_fast.json",
                    help="benchmark artifact written by benchmarks.run")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="committed baseline for the delta column")
    args = ap.parse_args()
    print(render(args.json, args.baseline))


if __name__ == "__main__":
    main()
