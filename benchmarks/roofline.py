"""Roofline summary: reads the dry-run JSONs and emits per-cell terms.

Model-FLOPs ratio: MODEL_FLOPS = 6*N_active*tokens (train) or
2*N_active*tokens (prefill/decode forward), divided over devices, against
the compiled per-device HLO FLOPs — the useful-compute fraction.
"""
from __future__ import annotations

import json
from pathlib import Path

import jax

DRYRUN_DIR = Path("experiments/dryrun")


def _active_params(arch: str) -> float:
    from repro.configs import get_config
    from repro.models.model import init_params

    cfg = get_config(arch)
    shapes = jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0)
    )
    total = sum(x.size for x in jax.tree.leaves(shapes))
    expert = 0
    for gi, (pattern, repeats) in enumerate(cfg.groups):
        for i, spec in enumerate(pattern):
            if spec.ffn == "moe":
                ffn = shapes["groups"][gi][str(i)]["ffn"]
                for nm in ("w_gate", "w_up", "w_down"):
                    expert += ffn[nm].size
    if cfg.moe_experts:
        total -= expert * (1 - cfg.moe_top_k / cfg.moe_experts)
    return float(total)


def model_flops(arch: str, shape_kind: str, seq: int, batch: int) -> float:
    n = _active_params(arch)
    if shape_kind == "train":
        return 6.0 * n * seq * batch
    if shape_kind == "prefill":
        return 2.0 * n * seq * batch
    return 2.0 * n * batch  # decode: one token per sequence


SHAPE_INFO = {
    "train_4k": ("train", 4096, 256),
    "prefill_32k": ("prefill", 32768, 32),
    "decode_32k": ("decode", 32768, 128),
    "long_500k": ("decode", 524288, 1),
}


def load_cells(mesh: str = "16x16") -> list[dict]:
    cells = []
    for path in sorted(DRYRUN_DIR.glob(f"*__{mesh}.json")):
        cells.append(json.loads(path.read_text()))
    return cells


def rows() -> list[tuple[str, float, str]]:
    out = _rows_for(DRYRUN_DIR, "roofline")
    opt = Path("experiments/dryrun_opt")
    if opt.exists():
        out += _rows_for(opt, "roofline_opt")
    return out


def _rows_for(dirpath: Path, prefix: str) -> list[tuple[str, float, str]]:
    out = []
    active = {}
    for path in sorted(dirpath.glob("*__16x16.json")):
        cell = json.loads(path.read_text())
        arch, shape = cell["arch"], cell["shape"]
        kind, seq, batch = SHAPE_INFO[shape]
        if arch not in active:
            active[arch] = _active_params(arch)
        mf = model_flops(arch, kind, seq, batch) / cell["devices"]
        # prefer the scan-trip-count-corrected terms (EXPERIMENTS.md
        # §Methodology); fall back to raw for old artifacts
        if "corrected" in cell:
            hlo_f = cell["corrected"]["flops_per_device"]
            rt = cell["roofline_corrected"]
            dominant = cell["bottleneck_corrected"]
        else:
            hlo_f = cell["flops_per_device"]
            rt = cell["roofline"]
            dominant = cell["bottleneck"]
        dom_s = rt[f"{dominant}_s"] if rt.get(f"{dominant}_s") else 0.0
        useful = mf / hlo_f if hlo_f and hlo_f > 0 else float("nan")
        # roofline fraction: ideal compute time / dominant term
        ideal = mf / 197e12
        frac = ideal / dom_s if dom_s else float("nan")
        out.append(
            (
                f"{prefix}/{arch}/{shape}",
                dom_s * 1e6,
                f"bottleneck={dominant} compute_s={rt['compute_s']:.4g} "
                f"memory_s={rt['memory_s']:.4g} collective_s={rt['collective_s']:.4g} "
                f"model/hlo_flops={useful:.3f} roofline_frac={frac:.4f}",
            )
        )
    return out
