"""Paper Table 3 analogue: client-runtime memory footprint.

The paper reports 27.3 MB RSS for its Go client (idle 26.0 MiB, peak
29.0 MiB under load). We measure the Python-object footprint of the
platform client (tracemalloc — excludes the interpreter itself, which is
the honest analogue of measuring the Go binary's RES minus the runtime)
idle and under a 50-task burst.
"""
from __future__ import annotations

import tracemalloc

from repro.core import EdgeClient, User, make_platform

BURST_PAYLOAD = """
import autospada
for i in range(5):
    autospada.publish({"i": i})
"""


def run() -> dict[str, float]:
    tracemalloc.start()
    base, _ = tracemalloc.get_traced_memory()
    store, broker, (server,) = make_platform()
    client = EdgeClient("veh-0", server, broker)
    client.bootstrap()
    client.run_until_idle()
    idle, _ = tracemalloc.get_traced_memory()

    user = User(server, broker)
    payload = user.payload(BURST_PAYLOAD)
    _assigns = [  # bound so the 50 live assignments stay in the heap
        user.assignment(f"b{i}", [user.task("veh-0", payload)]).commit()
        for i in range(50)
    ]
    client.run_until_idle()
    cur, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return {
        "idle_mb": (idle - base) / 1e6,
        "loaded_mb": (cur - base) / 1e6,
        "peak_mb": (peak - base) / 1e6,
    }


def rows() -> list[tuple[str, float, str]]:
    r = run()
    return [
        ("table3/client_idle", r["idle_mb"] * 1e3, f"{r['idle_mb']:.2f} MB (paper Go client: 26.0 MiB idle)"),
        ("table3/client_peak_50tasks", r["peak_mb"] * 1e3, f"{r['peak_mb']:.2f} MB peak (paper: 29.0 MiB peak)"),
    ]
