"""Closed-loop analyst load against the fleet gateway (ROADMAP item 5).

Many concurrent `AnalystSession`s, each keeping exactly one request in
flight against a running `FleetSimulator` fronted by
`repro.serve.FleetGateway`: a session fires its next query the moment
the previous response lands (closed-loop load, so the offered rate
tracks service capacity instead of overrunning it). The request mix
cycles dashboard gauges, platform doc counts, fleet-level window
statistics, percentile queries, and per-vehicle signal windows — the
read side of the paper's analyst workflow.

Two sections, CSV rows like the rest of the harness:

* ``serve/read_*`` — per-query cost of the statistics read path at
  N=10k: the gateway's answer out of the *cached per-tick sketch fold*
  (`FleetSignalPlane.fleet_sketch` — one device fold per tick shared by
  every analyst and every vehicle payload) vs the same answer with the
  cache defeated (a fresh `compute_sketches` device fold per query —
  what serving would cost without the cache). The cached path must win
  by >= 3x in BOTH modes (CI guard): the gap is asymptotic — O(N)
  merge of an already-folded sketch block vs a full ring fold — so it
  holds at the benchmarked N even on throttled shared runners.
* ``serve/closed_loop_*`` — end-to-end gateway throughput: S analyst
  sessions in closed loop over a 10k-vehicle fleet (100k too in full
  mode), admissions capped per tick boundary so backpressure turns into
  queueing delay. Reports queries/sec (wall) and p50/p99 response
  latency in world ticks. Informational — wall-clock throughput races
  the runner, so the hard floor stays on the read-path ratio above.

Run: ``PYTHONPATH=src python -m benchmarks.serve_load [--fast]``
(exits non-zero if the cached read path loses its floor).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from benchmarks.fleet_scale import _time_pair

#: fleet size for the guarded read-path ratio and the fast closed loop —
#: the ISSUE-10 acceptance bar is N >= 10k
SERVE_N = 10_000
#: full mode also drives the closed loop at campaign scale
SERVE_N_FULL = 100_000
#: concurrent analyst sessions in the closed loop
SERVE_SESSIONS = 32
#: responses collected per closed-loop run
SERVE_QUERIES_FAST, SERVE_QUERIES = 160, 480
#: admissions per tick boundary: < SESSIONS so overload shows up as
#: deterministic queueing delay (the p99 - p50 spread), not tick blowup
SERVE_ADMIT_PER_TICK = 8
#: signal the statistics queries sketch, and its windowing
SERVE_SIGNAL = "Vehicle.FuelRate"
SERVE_WINDOW = 64
#: history ring depth: enough for the window plus slack, small enough
#: that the 100k build stays cheap
SERVE_HISTORY = 96
#: mostly-idle service so ticks cost O(due), not O(N)
SERVE_RESYNC = 64
#: acceptance floor for the cached-fold read path vs a per-query fold —
#: a hard floor in BOTH modes (asymptotic gap, see module docstring)
SERVE_READ_TARGET_SPEEDUP = 3.0

#: the closed-loop request mix each session cycles through (index-driven,
#: so a trace is a pure function of session count and query budget)
_MIX = ("gauges", "fleet_stats", "quantile", "window", "platform")


def _build(n: int):
    from repro.fleet.simulator import Backends, FleetSimulator, SimConfig
    from repro.serve.gateway import FleetGateway

    sim = FleetSimulator(
        SimConfig(
            n_clients=n,
            seed=3,
            scenario="mixed",
            signal_history=SERVE_HISTORY,
            resync_period=SERVE_RESYNC,
            backends=Backends(service="calendar"),
        )
    )
    for _ in range(SERVE_WINDOW + 4):  # fill the window every query reads
        sim.tick()
    return sim, FleetGateway(sim, admit_per_tick=SERVE_ADMIT_PER_TICK)


def _issue(sess, i: int, n: int):
    """One request from the deterministic mix (i = the session's query
    counter): statistics reads dominate, vehicle reads rotate rows."""
    kind = _MIX[i % len(_MIX)]
    if kind == "fleet_stats":
        return sess.fleet_stats(SERVE_SIGNAL, window=SERVE_WINDOW)
    if kind == "quantile":
        return sess.quantile(SERVE_SIGNAL, 0.9, window=SERVE_WINDOW)
    if kind == "window":
        return sess.window((37 * i) % n, SERVE_SIGNAL, 8)
    return sess.ask(kind)


def read_path_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """The guarded ratio: one analyst statistics query served from the
    per-tick sketch cache vs the same query with the cache defeated
    (every query pays its own `compute_sketches` ring fold)."""
    n = SERVE_N
    reps = 3 if fast else 5
    sim, gw = _build(n)
    plane = sim.plane
    params = {"signal": SERVE_SIGNAL, "q": 0.9, "window": SERVE_WINDOW}

    def cached() -> dict:
        return gw._read_quantile(params)

    def cold() -> dict:
        plane._sketch_cache.clear()
        gw._stats_cache.clear()
        return gw._read_quantile(params)

    warm = cold()  # compile the fold + merges, prime the cache
    assert cached() == warm, "cached read diverged from the cold fold"
    t_cold, t_cached = _time_pair(cold, cached, reps)
    speedups = {n: t_cold / t_cached}
    return [
        (
            f"serve/read_cold_fold_N{n}",
            t_cold,
            f"per-query ring fold, no cache, W={SERVE_WINDOW}",
        ),
        (
            f"serve/read_cached_N{n}",
            t_cached,
            f"{speedups[n]:.1f}x vs per-query fold "
            f"(one shared fold per tick)",
        ),
    ], speedups


def closed_loop_rows(fast: bool) -> list[tuple[str, float, str]]:
    """S sessions, one request in flight each, over the N=10k fleet
    (100k too in full mode): queries/sec and response-tick percentiles
    under the per-tick admission cap."""
    sizes = (SERVE_N,) if fast else (SERVE_N, SERVE_N_FULL)
    total = SERVE_QUERIES_FAST if fast else SERVE_QUERIES
    rows = []
    for n in sizes:
        sim, gw = _build(n)
        sessions = [gw.session(f"load-{s}") for s in range(SERVE_SESSIONS)]
        counters = dict.fromkeys(range(SERVE_SESSIONS), 0)
        tickets: dict[int, object] = {}
        latencies: list[int] = []
        issued = 0
        t0 = time.perf_counter()
        for s in range(SERVE_SESSIONS):
            tickets[s] = _issue(sessions[s], 0, n)
            counters[s] = 1
            issued += 1
        while len(latencies) < total:
            gw.tick()
            for s in range(SERVE_SESSIONS):
                t = tickets.get(s)
                if t is None or not t.done:
                    continue
                latencies.append(t.response.ticks)
                if issued < total:
                    tickets[s] = _issue(sessions[s], counters[s], n)
                    counters[s] += 1
                    issued += 1
                else:
                    tickets[s] = None
        wall = time.perf_counter() - t0
        lat = np.asarray(latencies[:total], np.float64)
        qps = total / max(wall, 1e-9)
        rows.append(
            (
                f"serve/closed_loop_N{n}_S{SERVE_SESSIONS}",
                wall / total * 1e6,
                f"{qps:.0f} queries/s closed-loop, response ticks "
                f"p50={np.percentile(lat, 50):.0f} "
                f"p99={np.percentile(lat, 99):.0f}, "
                f"admit cap {SERVE_ADMIT_PER_TICK}/tick",
            )
        )
    return rows


def rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """All serve rows plus the guarded read-path speedup, keyed by N
    (the ``serve`` section of the benchmark JSON)."""
    read_rows, speedups = read_path_rows(fast)
    return read_rows + closed_loop_rows(fast), speedups


def check_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    """Hard floor in BOTH modes: the cached-fold analyst read path must
    beat a per-query ring fold by >= 3x (see module docstring)."""
    n_max = max(speedups)
    if speedups[n_max] < SERVE_READ_TARGET_SPEEDUP:
        return (
            f"gateway cached-fold read path speedup at N={n_max} is "
            f"{speedups[n_max]:.1f}x < "
            f"{SERVE_READ_TARGET_SPEEDUP:.0f}x floor"
        )
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke sizes")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    all_rows, speedups = rows(args.fast)
    for name, us, derived in all_rows:
        print(f"{name},{us:.2f},{derived}")
    err = check_guard(speedups, fast=args.fast)
    if err:
        print(f"serve/guard_failed,0,{err}")
        sys.exit(1)


if __name__ == "__main__":
    main()
