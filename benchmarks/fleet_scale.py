"""Fleet-scale benchmark: vectorized delta aggregation, the columnar
signal plane, the event-driven service scheduler, fused windowed
sketches, plane growth, and simulator throughput.

Eight sections, CSV rows like the rest of the harness:

* ``fleet/agg_*`` — FedAvg server-step latency over N packed int8 deltas,
  per-client reference loop (`aggregate_reference`) vs the batched
  vmap+einsum path (`aggregate_packed`), at N in {32, 256, 1024}. The
  batched path must win at every N (CI guard) and by >= 5x at N=1024.
* ``fleet/plane_*`` — per-tick fleet signal cost: the legacy per-vehicle
  `ScriptedSignalBroker` tick loop (N brokers x n_signals Python
  iterators + subscriber callbacks) vs ONE `FleetSignalPlane.step` (a
  single jit'd drive-cycle evaluation for the whole fleet) at N=1024.
  The plane must win at the largest N (CI guard; >= 2x in full mode).
* ``fleet/plane_sharded_*`` — per-tick fleet signal cost, single-host
  plane vs the device-sharded plane (`ShardedSignalPlane`: client rows
  split over a `clients` mesh, one jit step with in/out shardings fusing
  the scenario eval with the in-place ring write). Bit-for-bit parity is
  asserted; the sharded step must stay within the smoke floor (and win
  in full mode).
* ``fleet/service_*`` — mostly-idle fleet tick: the dense O(N) poll loop
  (`DensePollService`, the parity oracle) vs the event-driven
  `FleetServiceScheduler` (wake hooks + vectorized phase gating,
  O(runnable) per tick) at N=1024. The scheduler must win at the largest
  N (CI guard; >= 3x in full mode) while producing identical broker
  counters.
* ``fleet/engine_*`` — the unified event engine: one full simulator tick
  (churn + broker + plane + service) on a mostly-idle N=4096 fleet under
  light ignition churn with a live 32-task assignment, legacy dense tick
  (O(N) churn scan + O(N) poll service) vs the time-ordered event heap
  (`EventEngine` + `EngineService`: O(events) per tick). Interleaved over
  the same tick sequence; broker counters must match bit-for-bit and the
  engine must win by >= 3x even in ``--fast`` (the ISSUE-6 tentpole
  claim, guarded in CI).
* ``fleet/sketch_*`` — fleet-wide windowed analytics: folding every
  vehicle's last-64 signal observations into Welford/histogram/quantile
  sketches, per-vehicle host loop (ring synced device->host, then N
  `sketch_reference` Python folds — what `ANALYTICS_PAYLOAD` costs) vs
  ONE fused device fold over the sharded ring (`compute_sketches`) at
  N=4096. Bit-for-bit parity is asserted in-bench, the ring must not
  cross device->host on the fused path (`ring_syncs` stays flat), and
  the fold must win by >= 3x even in ``--fast`` (the ISSUE-7 tentpole
  claim, guarded in CI).
* ``fleet/grow_*`` — mass admission: N `FleetSignalPlane.add_client`
  joins with exact per-join regrowth (the pre-amortization path: one XLA
  recompile + full history-ring realloc per join) vs geometric capacity
  doubling (O(log N) regrows). Geometric must win (CI guard; >= 3x in
  full mode).
* ``fleet/ckpt_*`` — durable fleet state: one whole-platform
  `FleetCheckpoint.save` and `restore` of an N=4096 world (manifest +
  content-addressed npy blobs). Guarded by a generous wall-time budget
  rather than a speedup — there is no per-client baseline, only a
  ceiling pathological serialization would blow (CI guard).
* ``fleet/sim_*`` — end-to-end discrete-event simulation: >= 1000 clients,
  >= 5 FedAvg rounds under a seeded lossy-broker schedule with stragglers,
  reporting clients/sec. In full (non ``--fast``) mode the run is repeated
  with the same seed and the final aggregates must match bit-for-bit.
* ``fleet/scale_*`` — the ISSUE-9 scaling curve: whole-world build cost,
  mostly-idle tick throughput (client-ticks/sec via the calendar-queue
  service), and the measured `memory_report` bytes/client at N in
  {1k, 10k, 100k}. The guard is structural, not a timing race: the
  columnar arena's per-row footprint must undercut an object-per-vehicle
  facsimile (one Python dict of the same seven control-plane scalars per
  client) by >= 3x in BOTH modes — `__slots__` or column regressions
  show up as bytes, not noise. ``--curve`` prints only this section.

Guarded timings are **best-of-k** (k >= 3): minima are far more stable
than medians on contended shared CI runners, so the guards catch code
regressions, not scheduler noise.

Run: ``PYTHONPATH=src python -m benchmarks.fleet_scale [--fast]``
(exits non-zero if a vectorized path loses to its per-client loop).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

AGG_SIZES = (32, 256, 1024)
#: delta length per client — matches the wire chunk (`ROUND_PAYLOAD`'s
#: row=256) so the per-client loop pays its real per-message Python cost
AGG_DIM = 256
AGG_ROW = 256
#: acceptance floor for the batched aggregation path at the largest N
TARGET_SPEEDUP_AT_MAX = 5.0
#: acceptance floor for the signal plane vs the per-vehicle tick loop
PLANE_TARGET_SPEEDUP = 2.0
PLANE_SIZES_FAST = (256,)
PLANE_SIZES = (256, 1024)
#: sharded-plane step vs the single-host plane step. The sharded step
#: fuses the scenario eval with the (donated, in-place) ring-slot write
#: and never blocks on a host transfer, so it should win outright in
#: full mode; the smoke floor only catches real regressions (e.g. an
#: accidental per-tick device->host sync, which shows up as ~5x slower)
#: without flaking on shared-runner noise at the small fast-mode N.
SHARDED_MIN_SPEEDUP = 0.7
SHARDED_TARGET_SPEEDUP = 1.0
SHARDED_N_FAST, SHARDED_N = 256, 1024
#: acceptance floor for the event-driven scheduler vs the dense poll loop
#: on a mostly-idle fleet tick (the ISSUE-4 tentpole claim)
SERVICE_TARGET_SPEEDUP = 3.0
SERVICE_N_FAST, SERVICE_N = 256, 1024
#: mostly-idle: only ~N/SERVICE_RESYNC clients dial in per tick
SERVICE_RESYNC = 64
#: acceptance floor for the unified event heap vs the legacy dense tick
#: on a mostly-idle fleet — a hard floor in BOTH modes: the gap is
#: asymptotic (O(events) vs O(N)), so it holds at the benchmarked N even
#: on throttled shared runners
ENGINE_TARGET_SPEEDUP = 3.0
#: the tentpole claim is pinned at fleet scale in fast mode too
ENGINE_N = 4096
#: mostly-idle: ~N/ENGINE_RESYNC clients (1.6%) dial in per tick
ENGINE_RESYNC = 64
#: a sprinkle of ignition churn + one 32-task assignment keep real events
#: (toggles, wakes, status messages) flowing so the in-bench counter
#: parity assert is non-vacuous
ENGINE_P_LEAVE, ENGINE_P_RETURN, ENGINE_TASKS = 0.0005, 0.2, 32
#: acceptance floor for the fused device sketch fold vs the per-vehicle
#: host loop — a hard floor in BOTH modes: the gap is asymptotic (one
#: fused kernel call vs N Python Welford loops plus a ring sync), so it
#: holds at the benchmarked N even on throttled shared runners
SKETCH_TARGET_SPEEDUP = 3.0
#: the tentpole claim is pinned at fleet scale in fast mode too
SKETCH_N = 4096
SKETCH_WINDOW = 64
#: whole-platform checkpoint save/restore budgets at fleet scale
#: (``fleet/ckpt_*``): generous wall-time ceilings — measured ~1.1s each
#: at N=4096 on a dev box — that catch pathological regressions (per-
#: vehicle file writes, an accidental O(N^2) codec) without flaking on
#: throttled shared runners
CKPT_N = 4096
CKPT_MAX_SAVE_S = 15.0
CKPT_MAX_RESTORE_S = 15.0
#: acceptance floor for geometric plane growth vs exact per-join regrowth
GROW_TARGET_SPEEDUP = 3.0
#: every exact-path join is an XLA recompile (~0.5s), so joins drive this
#: section's wall time; 12 fast joins (12 vs 2 recompiles) already shows
#: the O(N)-vs-O(log N) gap without burning half a minute of CI smoke
GROW_JOINS_FAST, GROW_JOINS = 12, 32
#: the ISSUE-9 scaling curve — N=100k stays in ``--fast`` too (the build
#: is ~7s and 20 mostly-idle calendar ticks are ~0.15s, so the campaign
#: headline rides free in the CI smoke job)
SCALE_SIZES = (1_000, 10_000, 100_000)
SCALE_TICKS = 20
#: mostly-idle: ~N/SCALE_RESYNC clients dial in per tick
SCALE_RESYNC = 64
#: structural floor for the columnar arena vs one Python dict of the same
#: seven control-plane scalars per vehicle — holds in BOTH modes (it is a
#: bytes ratio, immune to runner throttling)
SCALE_COLUMNS_ADVANTAGE = 3.0


def _synthetic_msgs(n: int, seed: int = 0) -> list[dict]:
    from repro.fleet.rounds import pack_delta

    rng = np.random.default_rng(seed)
    return [
        pack_delta(rng.standard_normal(AGG_DIM).astype(np.float32), row=AGG_ROW)
        for _ in range(n)
    ]


def _time(fn, reps: int) -> float:
    """Best-of-k timing (k = reps, always >= 3): the minimum is the least
    contention-polluted sample, so guard comparisons don't flake when a
    shared runner throttles mid-measurement."""
    samples = []
    for _ in range(max(3, reps)):
        t0 = time.perf_counter()
        fn()
        samples.append(time.perf_counter() - t0)
    return float(np.min(samples)) * 1e6  # us


def _time_pair(fn_a, fn_b, reps: int) -> tuple[float, float]:
    """Interleaved best-of-k timing: alternating samples decorrelate the
    two measurements from CPU-contention drift, and taking each side's
    minimum (not median) keeps the guarded ratio stable on noisy shared
    CI runners."""
    a, b = [], []
    for _ in range(max(3, reps)):
        t0 = time.perf_counter()
        fn_a()
        a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fn_b()
        b.append(time.perf_counter() - t0)
    return float(np.min(a)) * 1e6, float(np.min(b)) * 1e6


def aggregation_rows(fast: bool) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Times the FedAvg server step over N decoded int8 deltas, each path
    on its working representation: the per-client dequantize-accumulate
    loop over numpy arrays (what `aggregate_reference` does after wire
    decode) vs the single batched einsum (`batched_dequant_mean`) over the
    stacked device array. Wire decode (base64 -> int8, identical for both
    paths) and the stack's host->device transfer are reported as their own
    rows so the decomposition is visible."""
    import jax.numpy as jnp

    from repro.fleet.compression import batched_dequant_mean
    from repro.fleet.rounds import stack_deltas

    reps = 5 if fast else 15
    rows, speedups = [], {}
    for n in AGG_SIZES:
        msgs = _synthetic_msgs(n, seed=n)
        q, s, _, _ = stack_deltas(msgs)
        qj, sj = jnp.asarray(q), jnp.asarray(s)
        per_client = [(q[i], s[i]) for i in range(n)]

        def ref_loop() -> np.ndarray:
            # the pre-vectorization hot path: per-client dequant, Python-
            # level accumulate (cf. the old np.mean(np.stack([...])) body)
            acc = np.zeros(q.shape[1] * q.shape[2], np.float32)
            for qi, si in per_client:
                acc += (qi.astype(np.float32) * si[:, None]).reshape(-1)
            return acc / n

        vec = batched_dequant_mean(qj, sj)  # warm-up: jit compile this shape
        assert np.allclose(ref_loop(), vec.reshape(-1), atol=1e-5), (
            "batched path diverged"
        )
        t_decode = _time(lambda: stack_deltas(msgs), reps)
        t_dev = _time(lambda: jnp.asarray(q).block_until_ready(), reps)
        t_ref, t_vec = _time_pair(
            ref_loop, lambda: batched_dequant_mean(qj, sj), reps
        )
        speedups[n] = t_ref / t_vec
        rows.append(
            (f"fleet/wire_decode_N{n}", t_decode, f"{n} deltas, dim={AGG_DIM}")
        )
        rows.append(
            (f"fleet/to_device_N{n}", t_dev, "stacked int8 host->device")
        )
        rows.append(
            (f"fleet/agg_per_client_N{n}", t_ref, f"{n} deltas, dim={AGG_DIM}")
        )
        note = "" if n > 64 else " (dispatch-bound at small N)"
        rows.append(
            (
                f"fleet/agg_batched_N{n}",
                t_vec,
                f"{speedups[n]:.1f}x vs per-client loop{note}",
            )
        )
    return rows, speedups


def signal_plane_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Per-tick signal cost for the whole fleet, both plumbing generations
    on the same seeded drive-cycle streams:

    * baseline — the pre-plane hot path: N `ScriptedSignalBroker`s (one
      per vehicle), each feeding a subscribed `SignalHandler` through
      per-signal Python iterators and callbacks, ticked in a loop;
    * plane — ONE `FleetSignalPlane.step()`: a single jit'd scenario
      evaluation producing the whole (N, n_signals) column block.
    """
    from repro.core.signals import SignalHandler
    from repro.fleet.scenarios import SIGNALS, Scenario, scripted_brokers

    reps = 10 if fast else 30
    sizes = PLANE_SIZES_FAST if fast else PLANE_SIZES
    rows, speedups = [], {}
    for n in sizes:
        scen = Scenario("mixed", seed=n)
        plane = scen.plane(n)
        plane.step()  # warm-up: jit compile the scenario step
        brokers = scripted_brokers(scen, n, reps + 4)
        handlers = [SignalHandler(b) for b in brokers]
        for h in handlers:  # subscribe every signal (the simulator state)
            for name in SIGNALS:
                h.ensure_subscribed(name)

        def old_tick() -> None:
            for b in brokers:
                b.tick()

        t_old, t_plane = _time_pair(old_tick, plane.step, reps)
        speedups[n] = t_old / t_plane
        rows.append(
            (
                f"fleet/plane_tick_loop_N{n}",
                t_old,
                f"{n} brokers x {len(SIGNALS)} signals, per-vehicle Python",
            )
        )
        rows.append(
            (
                f"fleet/plane_step_N{n}",
                t_plane,
                f"{speedups[n]:.1f}x vs per-vehicle tick loop",
            )
        )
    return rows, speedups


def plane_sharded_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Per-tick fleet signal cost, host vs device-sharded plane on the
    same seeded drive-cycle step: the host plane evaluates the jit'd
    scenario then syncs to a host array and writes the ring slot in
    numpy; the sharded plane runs ONE jit call (scenario + in-place ring
    write, client rows split across devices) and only syncs on read. The
    two must stay bit-for-bit identical — asserted here, sampled."""
    from repro.fleet.scenarios import Scenario

    n = SHARDED_N_FAST if fast else SHARDED_N
    reps = 10 if fast else 30
    scen = Scenario("mixed", seed=n)
    host, sharded = scen.plane(n), scen.sharded_plane(n)
    host.step()  # warm-up: compile both steps
    sharded.step()
    sharded.block_until_ready()

    def sharded_step() -> None:
        sharded.step()
        sharded.block_until_ready()  # fairness: host.step blocks too

    t_host, t_sharded = _time_pair(host.step, sharded_step, reps)
    assert np.array_equal(host.values, sharded.values), (
        "sharded plane diverged from the host plane"
    )
    speedups = {n: t_host / t_sharded}
    return [
        (
            f"fleet/plane_sharded_host_N{n}",
            t_host,
            f"single-host plane step, {n} rows",
        ),
        (
            f"fleet/plane_sharded_step_N{n}",
            t_sharded,
            f"{speedups[n]:.2f}x vs host plane; {sharded.devices} device(s), "
            f"capacity {sharded._capacity}",
        ),
    ], speedups


def service_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Mostly-idle fleet tick cost, both service generations on identical
    worlds: the dense O(N) poll loop (an `idle` check + `advance` per
    online vehicle per tick) vs the event-driven scheduler (wake hooks +
    vectorized phase masks, touching only runnable/resync-due clients).
    The two sims run interleaved over the same tick sequence and must end
    with identical broker counters — the parity contract, sampled."""
    from repro.fleet import FleetSimulator, SimConfig

    n = SERVICE_N_FAST if fast else SERVICE_N
    reps = 20 if fast else 40
    mk = lambda kind: FleetSimulator(
        SimConfig(
            n_clients=n, seed=3, resync_period=SERVICE_RESYNC, service=kind
        )
    )
    dense, sched = mk("dense"), mk("scheduler")
    t_dense, t_sched = _time_pair(dense.tick, sched.tick, reps)
    assert dense.t == sched.t and (
        dense.broker.published,
        dense.broker.delivered,
        dense.broker.dropped,
    ) == (
        sched.broker.published,
        sched.broker.delivered,
        sched.broker.dropped,
    ), "scheduler diverged from the dense oracle"
    speedups = {n: t_dense / t_sched}
    return [
        (
            f"fleet/service_dense_N{n}",
            t_dense,
            f"O(N) poll loop, {n} online mostly-idle clients/tick",
        ),
        (
            f"fleet/service_sched_N{n}",
            t_sched,
            f"{speedups[n]:.1f}x vs dense poll; "
            f"{sched.service.last_serviced} of {n} clients touched",
        ),
    ], speedups


def engine_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Whole-tick cost of the unified event engine vs the legacy dense
    tick on identical mostly-idle worlds (N=4096, ~1.6% of clients due
    per tick, light ignition churn, one live 32-task assignment):

    * dense — the per-subsystem oracle: O(N) churn scan + broker advance
      + plane step + O(N) poll service, every tick;
    * engine — ONE time-ordered heap drain (`EventEngine`): churn
      toggles, service token-bucket refills, and straggler releases all
      fire as events, so the tick costs O(events actually due).

    The two sims run interleaved over the same tick sequence and must
    end with identical broker counters — the parity contract, sampled
    (tests/test_engine.py asserts the full bit-for-bit grid)."""
    from repro.fleet import Backends, FleetSimulator, SimConfig

    n = ENGINE_N
    reps = 10 if fast else 30

    def mk(backends: Backends) -> FleetSimulator:
        sim = FleetSimulator(
            SimConfig(
                n_clients=n, seed=3, resync_period=ENGINE_RESYNC,
                p_leave=ENGINE_P_LEAVE, p_return=ENGINE_P_RETURN,
                backends=backends,
            )
        )
        payload = sim.user.payload(
            "import autospada\nautospada.publish({'ok': 1})\n"
        )
        cids = sim.user.online_clients()[:ENGINE_TASKS]
        sim.user.assignment(
            "bench", [sim.user.task(c, payload) for c in cids]
        ).commit()
        return sim

    dense = mk(Backends(engine="dense", service="dense", churn="dense"))
    engine = mk(Backends(engine="event", service="scheduler", churn="event"))
    t_dense, t_engine = _time_pair(dense.tick, engine.tick, reps)
    assert dense.t == engine.t and (
        dense.broker.published,
        dense.broker.delivered,
        dense.broker.dropped,
    ) == (
        engine.broker.published,
        engine.broker.delivered,
        engine.broker.dropped,
    ), "event engine diverged from the dense tick oracle"
    assert engine.broker.published > 0, "parity assert was vacuous"
    speedups = {n: t_dense / t_engine}
    return [
        (
            f"fleet/engine_dense_N{n}",
            t_dense,
            f"legacy dense tick: O(N) churn scan + O(N) poll, {n} clients",
        ),
        (
            f"fleet/engine_heap_N{n}",
            t_engine,
            f"{speedups[n]:.1f}x vs dense tick; "
            f"{engine.service.last_serviced} of {n} clients touched, "
            f"{len(engine.engine)} events pending",
        ),
    ], speedups


def sketch_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Fleet-wide windowed-sketch cost on a device-sharded signal ring,
    both analytics generations over identical windows:

    * baseline — what N `ANALYTICS_PAYLOAD` sandboxes cost the host: one
      device->host ring sync (`window()` forces it, re-dirtied per rep),
      then N per-vehicle Python folds (`sketch_reference` — f32 Welford,
      edge binning, ranked quantile selection);
    * fused — ONE `compute_sketches` call folding every client's window
      in place on the ring's device shards; only the `(dim, N)` sketch
      block crosses device->host.

    Bit-for-bit parity (moments/hist/quantile values) is asserted here,
    and so is the no-transfer claim: the fused path must leave the host
    mirror cold (`_hist_dirty` stays set, `ring_syncs` stays flat)."""
    from repro.fleet.scenarios import Scenario
    from repro.kernels.sketch import SketchSpec, sketch_reference

    n = SKETCH_N
    reps = 3 if fast else 5
    sig = "Vehicle.FuelRate"
    spec = SketchSpec(window=SKETCH_WINDOW)
    plane = Scenario("mixed", seed=11).sharded_plane(n, history=128)
    for _ in range(SKETCH_WINDOW + 4):
        plane.step()
    plane.block_until_ready()

    def host_folds() -> list[dict]:
        plane._hist_dirty = True  # each rep pays the ring sync, like a tick
        return [
            sketch_reference(plane.window(i, sig, spec.window), spec)
            for i in range(n)
        ]

    sk = plane.compute_sketches(sig, spec)  # warm-up: compile the fold
    for i, ref in enumerate(host_folds()):  # parity contract, full fleet
        assert sk.row(i) == ref, f"fused sketch diverged at row {i}"

    t_host, t_fused = _time_pair(
        host_folds, lambda: plane.compute_sketches(sig, spec), reps
    )
    # the no-transfer claim: the fused fold must not warm the host mirror
    plane._hist_dirty = True
    syncs0 = plane.ring_syncs
    plane.compute_sketches(sig, spec)
    assert plane._hist_dirty and plane.ring_syncs == syncs0, (
        "fused sketch path synced the ring device->host"
    )
    speedups = {n: t_host / t_fused}
    return [
        (
            f"fleet/sketch_host_N{n}",
            t_host,
            f"ring sync + {n} per-vehicle Python folds, W={SKETCH_WINDOW}",
        ),
        (
            f"fleet/sketch_fused_N{n}",
            t_fused,
            f"{speedups[n]:.1f}x vs per-vehicle host folds; "
            f"{plane.devices} device(s), ring never leaves device",
        ),
    ], speedups


def plane_growth_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Mass-admission cost: N `add_client` joins on a jit drive-cycle
    plane. `growth=1.0` is the pre-amortization path — every join rebuilds
    the series (an XLA recompile of the scenario step) and reallocates the
    whole history ring; `growth=2.0` doubles capacity so both costs are
    paid O(log N) times."""
    from repro.core.signals import FleetSignalPlane
    from repro.fleet.scenarios import SIGNALS, Scenario

    joins = GROW_JOINS_FAST if fast else GROW_JOINS
    reps = 3  # each rep recompiles; best-of-3 still bounds the noise
    scen = Scenario("mixed", seed=5)

    def admit(growth: float) -> None:
        plane = FleetSignalPlane(
            SIGNALS, scen.series(8), history=64,
            grow_fn=scen.series, growth=growth,
        )
        plane.step()
        for _ in range(joins):
            plane.add_client()

    admit(2.0)  # warm-up: jax dispatch machinery, first-compile overheads
    t_exact, t_geo = _time_pair(
        lambda: admit(1.0), lambda: admit(2.0), reps
    )
    speedups = {joins: t_exact / t_geo}
    return [
        (
            f"fleet/grow_exact_J{joins}",
            t_exact,
            f"{joins} joins, regrow+recompile per join",
        ),
        (
            f"fleet/grow_geometric_J{joins}",
            t_geo,
            f"{speedups[joins]:.1f}x vs exact regrowth "
            f"(capacity doubling, O(log N) recompiles)",
        ),
    ], speedups


def checkpoint_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """Durable-fleet-state cost at fleet scale: one whole-platform
    `FleetCheckpoint.save` (broker + documents + vehicles + plane ring +
    engine heap -> manifest + content-addressed npy blobs) and one
    `restore` (fresh simulator build + state overwrite) of an N=4096
    world with a completed FedAvg round in flight history. The guard is
    a wall-time budget, not a speedup: there is no per-client baseline
    to race, only a ceiling that pathological serialization would blow."""
    import shutil
    import tempfile
    from pathlib import Path

    from repro.fleet import FedConfig, FleetSimulator, SimConfig
    from repro.fleet.checkpoint import FleetCheckpoint

    n = CKPT_N
    reps = 3
    sim = FleetSimulator(
        SimConfig(
            n_clients=n, seed=9, p_drop=0.05, max_delay=2,
            straggler_fraction=0.1,
        )
    )
    drv = sim.run_federated(
        FedConfig(
            local_steps=1, local_lr=0.2, deadline_fraction=0.9,
            deadline_pumps=48,
        ),
        dim=32, rounds=1, n_samples=8,
    )
    root = Path(tempfile.mkdtemp(prefix="fleet-ckpt-bench-"))
    try:
        def save() -> None:
            shutil.rmtree(root / "ck", ignore_errors=True)
            FleetCheckpoint.save(sim, root / "ck", driver=drv)

        save()  # a checkpoint must exist before the first restore sample
        t_save = _time(save, reps)
        t_restore = _time(lambda: FleetCheckpoint.restore(root / "ck"), reps)
        blobs = len(list((root / "ck" / "arrays").glob("*.npy")))
    finally:
        shutil.rmtree(root, ignore_errors=True)
    # guard ratio: budget / measured — < 1.0 means the budget is blown
    speedups = {
        n: min(
            CKPT_MAX_SAVE_S * 1e6 / t_save,
            CKPT_MAX_RESTORE_S * 1e6 / t_restore,
        )
    }
    return [
        (
            f"fleet/ckpt_save_N{n}",
            t_save,
            f"whole-platform save, {blobs} content-addressed blobs, "
            f"{CKPT_MAX_SAVE_S:.0f}s budget",
        ),
        (
            f"fleet/ckpt_restore_N{n}",
            t_restore,
            f"fresh build + state overwrite, {CKPT_MAX_RESTORE_S:.0f}s budget",
        ),
    ], speedups


def _object_per_vehicle_facsimile(n: int) -> list[dict]:
    """What the pre-columnarization control plane kept per client: one
    Python mapping holding the seven per-vehicle scalars that now live as
    rows of the shared `FleetColumns` arena. Distinct int values keep the
    `deep_sizeof` memoizer from sharing interned small ints across
    clients, which would flatter the old layout."""
    return [
        dict(
            logical_clock=1000 + i, online=True, registered=False,
            client_ts=2000 + i, unacked=0, runnable=False, straggler=False,
        )
        for i in range(n)
    ]


def scale_rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[int, float]]:
    """The fleet-size scaling curve at N in {1k, 10k, 100k}: whole-world
    build cost (plane + columnar arena + calendar service, one sample —
    the 100k build is seconds, not microseconds), mostly-idle tick
    throughput in client-ticks/sec (best-of-k over ``SCALE_TICKS``-tick
    loops), and the measured `memory_report` bytes/client. The guarded
    ratio is structural: arena bytes/row vs `deep_sizeof` of an
    object-per-vehicle facsimile — a bytes comparison, so the >= 3x floor
    holds in both modes regardless of runner speed."""
    from repro.core.columns import deep_sizeof
    from repro.fleet import Backends, FleetSimulator, SimConfig

    reps = 3
    rows = []
    arena_row_bytes = 0.0
    for n in SCALE_SIZES:
        t0 = time.perf_counter()
        sim = FleetSimulator(
            SimConfig(
                n_clients=n, seed=3, p_leave=0.0005, p_return=0.2,
                straggler_fraction=0.1, resync_period=SCALE_RESYNC,
                signal_history=8, backends=Backends(service="calendar"),
            )
        )
        t_build = (time.perf_counter() - t0) * 1e6

        def tick_loop() -> None:
            for _ in range(SCALE_TICKS):
                sim.tick()

        t_tick = _time(tick_loop, reps) / SCALE_TICKS
        report = sim.memory_report()
        arena_row_bytes = sim.columns.nbytes() / sim.columns.capacity
        rows.append(
            (
                f"fleet/scale_build_N{n}",
                t_build,
                "plane + columnar arena + calendar lanes, single sample",
            )
        )
        rows.append(
            (
                f"fleet/scale_tick_N{n}",
                t_tick,
                f"{n / (t_tick / 1e6):,.0f} client-ticks/s mostly idle, "
                f"{report['bytes_per_client']:,.0f} B/client end to end",
            )
        )
    n_fac = 4096
    facsimile = deep_sizeof(_object_per_vehicle_facsimile(n_fac)) / n_fac
    advantage = facsimile / arena_row_bytes
    n_max = max(SCALE_SIZES)
    rows.append(
        (
            f"fleet/scale_arena_row_B_N{n_max}",
            arena_row_bytes,
            f"{advantage:.1f}x leaner than object-per-vehicle "
            f"({facsimile:.0f} B/client of Python scalars)",
        )
    )
    return rows, {n_max: advantage}


def simulator_rows(fast: bool) -> list[tuple[str, float, str]]:
    from repro.fleet import FedConfig, FleetSimulator, SimConfig

    n = 256 if fast else 1024
    rounds = 3 if fast else 5
    cfg = SimConfig(
        n_clients=n,
        seed=7,
        p_drop=0.05,
        p_duplicate=0.02,
        max_delay=2,
        straggler_fraction=0.1,
    )
    fed = FedConfig(
        local_steps=3, local_lr=0.2, deadline_fraction=0.9, deadline_pumps=64
    )

    def once():
        sim = FleetSimulator(cfg)
        drv = sim.run_federated(fed, dim=32, rounds=rounds, n_samples=16)
        return drv.w.copy(), sim.metrics.summary()

    w, s = once()
    deterministic = ""
    if not fast:
        w2, _ = once()
        assert np.array_equal(w, w2), "same seed must give the same aggregate"
        deterministic = "; deterministic (same seed => same aggregate)"
    us_per_client_round = s["wall_s"] / max(1, s["total_participants"]) * 1e6
    return [
        (
            f"fleet/sim_round_N{n}",
            us_per_client_round,
            f"{s['clients_per_sec']:.0f} clients/s over {s['rounds']} lossy "
            f"rounds, {s['dropped']} notifications dropped{deterministic}",
        )
    ]


def _measure_guarded(measure_fn, guard_fn, fast: bool):
    """Measure a section; on a tripped guard, re-measure once and keep
    the better speedup — shared runners throttle unpredictably and the
    guard should catch code, not noise."""
    section_rows, speedups = measure_fn(fast)
    if guard_fn(speedups, fast=fast) is not None:
        rows2, speedups2 = measure_fn(fast)
        if speedups2[max(speedups2)] > speedups[max(speedups)]:
            section_rows, speedups = rows2, speedups2
    return section_rows, speedups


def rows(
    fast: bool,
) -> tuple[list[tuple[str, float, str]], dict[str, dict[int, float]]]:
    """All fleet rows plus the vectorization speedups (for the CI guard),
    keyed by section: ``{"agg": {N: x}, "plane": {N: x}, "service":
    {N: x}, "grow": {joins: x}, "ckpt": {N: budget_headroom},
    "scale": {N: columnar_bytes_advantage}}``."""
    agg, agg_speedups = _measure_guarded(aggregation_rows, _agg_guard, fast)
    plane, plane_speedups = _measure_guarded(
        signal_plane_rows, _plane_guard, fast
    )
    sharded, sharded_speedups = _measure_guarded(
        plane_sharded_rows, _plane_sharded_guard, fast
    )
    service, service_speedups = _measure_guarded(
        service_rows, _service_guard, fast
    )
    engine, engine_speedups = _measure_guarded(engine_rows, _engine_guard, fast)
    sketch, sketch_speedups = _measure_guarded(sketch_rows, _sketch_guard, fast)
    grow, grow_speedups = _measure_guarded(plane_growth_rows, _grow_guard, fast)
    ckpt, ckpt_speedups = _measure_guarded(checkpoint_rows, _ckpt_guard, fast)
    scale, scale_speedups = _measure_guarded(scale_rows, _scale_guard, fast)
    guards = {
        "agg": agg_speedups,
        "plane": plane_speedups,
        "plane_sharded": sharded_speedups,
        "service": service_speedups,
        "engine": engine_speedups,
        "sketch": sketch_speedups,
        "grow": grow_speedups,
        "ckpt": ckpt_speedups,
        "scale": scale_speedups,
    }
    return (
        agg + plane + sharded + service + engine + sketch + grow + ckpt
        + scale + simulator_rows(fast),
        guards,
    )


def _agg_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    """The guard is evaluated at fleet scale (the largest benchmarked N):
    at N<=64 the batched path is dominated by fixed JAX dispatch overhead
    and losing there is expected, not a regression."""
    n_max = max(speedups)
    if speedups[n_max] < 1.0:
        return (
            f"vectorized aggregation slower than per-client loop at "
            f"N={n_max}: {speedups[n_max]:.2f}x"
        )
    if not fast and speedups[n_max] < TARGET_SPEEDUP_AT_MAX:
        return (
            f"batched aggregation speedup at N={n_max} is "
            f"{speedups[n_max]:.1f}x < {TARGET_SPEEDUP_AT_MAX:.0f}x target"
        )
    return None


def _plane_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    n_max = max(speedups)
    if speedups[n_max] < 1.0:
        return (
            f"signal plane step slower than per-vehicle tick loop at "
            f"N={n_max}: {speedups[n_max]:.2f}x"
        )
    if not fast and speedups[n_max] < PLANE_TARGET_SPEEDUP:
        return (
            f"signal plane speedup at N={n_max} is "
            f"{speedups[n_max]:.1f}x < {PLANE_TARGET_SPEEDUP:.0f}x target"
        )
    return None


def _plane_sharded_guard(
    speedups: dict[int, float], *, fast: bool
) -> str | None:
    n_max = max(speedups)
    if speedups[n_max] < SHARDED_MIN_SPEEDUP:
        return (
            f"sharded plane step fell behind the host plane at N={n_max}: "
            f"{speedups[n_max]:.2f}x < {SHARDED_MIN_SPEEDUP:.1f}x floor "
            f"(a per-tick host sync regression looks like this)"
        )
    if not fast and speedups[n_max] < SHARDED_TARGET_SPEEDUP:
        return (
            f"sharded plane speedup at N={n_max} is "
            f"{speedups[n_max]:.2f}x < {SHARDED_TARGET_SPEEDUP:.1f}x target"
        )
    return None


def _service_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    n_max = max(speedups)
    if speedups[n_max] < 1.0:
        return (
            f"event-driven scheduler slower than dense poll loop at "
            f"N={n_max}: {speedups[n_max]:.2f}x"
        )
    if not fast and speedups[n_max] < SERVICE_TARGET_SPEEDUP:
        return (
            f"scheduler speedup on a mostly-idle fleet tick at N={n_max} "
            f"is {speedups[n_max]:.1f}x < {SERVICE_TARGET_SPEEDUP:.0f}x target"
        )
    return None


def _engine_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    """Unlike the other sections, the 3x floor holds in ``--fast`` too:
    the engine-vs-dense gap is asymptotic (O(events) vs O(N) per tick)
    and the section always runs at fleet scale (N=4096), so falling
    under 3x means the heap path regressed, not that the runner is slow
    (measured headroom is ~2x above the floor)."""
    n_max = max(speedups)
    if speedups[n_max] < ENGINE_TARGET_SPEEDUP:
        return (
            f"event-engine tick speedup on a mostly-idle fleet at "
            f"N={n_max} is {speedups[n_max]:.1f}x < "
            f"{ENGINE_TARGET_SPEEDUP:.0f}x floor"
        )
    return None


def _sketch_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    """Like the engine guard, the 3x floor holds in ``--fast`` too: the
    fused-vs-host gap is asymptotic (one device fold vs N Python Welford
    loops plus a full ring transfer) and the section always runs at
    fleet scale (N=4096), so falling under 3x means the fused fold — or
    its stay-on-device property — regressed, not that the runner is
    slow (measured headroom is orders of magnitude above the floor)."""
    n_max = max(speedups)
    if speedups[n_max] < SKETCH_TARGET_SPEEDUP:
        return (
            f"fused sketch fold speedup at N={n_max} is "
            f"{speedups[n_max]:.1f}x < {SKETCH_TARGET_SPEEDUP:.0f}x floor"
        )
    return None


def _grow_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    j_max = max(speedups)
    if speedups[j_max] < 1.0:
        return (
            f"geometric plane growth slower than exact regrowth over "
            f"{j_max} joins: {speedups[j_max]:.2f}x"
        )
    if not fast and speedups[j_max] < GROW_TARGET_SPEEDUP:
        return (
            f"geometric plane-growth speedup over {j_max} joins is "
            f"{speedups[j_max]:.1f}x < {GROW_TARGET_SPEEDUP:.0f}x target"
        )
    return None


def _ckpt_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    """The ratio is budget/measured, identical in both modes: the section
    always runs at N=4096 and the budget is ~13x the measured cost, so
    tripping it means checkpoint serialization regressed massively."""
    n_max = max(speedups)
    if speedups[n_max] < 1.0:
        return (
            f"fleet checkpoint save/restore at N={n_max} blew its "
            f"{CKPT_MAX_SAVE_S:.0f}s wall-time budget "
            f"({speedups[n_max]:.2f}x headroom)"
        )
    return None


def _scale_guard(speedups: dict[int, float], *, fast: bool) -> str | None:
    """A bytes ratio, not a timing: the columnar arena's per-row footprint
    vs one Python dict of the same scalars per vehicle. Structural, so
    the floor holds in BOTH modes — tripping it means per-client state
    grew back into Python objects (a dropped ``__slots__``, a scalar
    moved out of the arena), not that the runner was slow."""
    n_max = max(speedups)
    if speedups[n_max] < SCALE_COLUMNS_ADVANTAGE:
        return (
            f"columnar arena only {speedups[n_max]:.1f}x leaner than the "
            f"object-per-vehicle facsimile at N={n_max} "
            f"(< {SCALE_COLUMNS_ADVANTAGE:.0f}x floor)"
        )
    return None


_GUARDS = {
    "agg": _agg_guard,
    "plane": _plane_guard,
    "plane_sharded": _plane_sharded_guard,
    "service": _service_guard,
    "engine": _engine_guard,
    "sketch": _sketch_guard,
    "grow": _grow_guard,
    "ckpt": _ckpt_guard,
    "scale": _scale_guard,
}


def check_guard(
    speedups: dict[str, dict[int, float]], *, fast: bool
) -> str | None:
    """Returns an error string if any vectorized/event-driven path
    regressed against its per-client Python baseline."""
    for section, guard in _GUARDS.items():
        if section in speedups:
            err = guard(speedups[section], fast=fast)
            if err:
                return err
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke sizes")
    ap.add_argument(
        "--curve",
        action="store_true",
        help="only the fleet-size scaling curve (build cost, client-ticks/s "
        "and bytes/client at N in {1k, 10k, 100k})",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.curve:
        all_rows, scale_speedups = scale_rows(args.fast)
        speedups = {"scale": scale_speedups}
    else:
        all_rows, speedups = rows(args.fast)
    for name, us, derived in all_rows:
        print(f"{name},{us:.2f},{derived}")
    err = check_guard(speedups, fast=args.fast)
    if err:
        print(f"fleet/guard_failed,0,{err}")
        sys.exit(1)


if __name__ == "__main__":
    main()
